//! SA core: the reconfigurable `TILE_R × TILE_C` two-dimensional array of
//! PEs inside each lane's SAU (paper Sec. II-B).
//!
//! Functional semantics of one `vsam.mac[z]`: stream `steps` unified
//! elements; at step `k`, row `r` receives input element `A[r][k]` and
//! column `c` receives weight element `B[c][k]`; PE `(r,c)` accumulates
//! `dot(A[r][k], B[c][k])`. I.e. the tile computes `ACC += A · Bᵀ` with a
//! unified-element inner dimension — three levels of parallelism:
//! input channels inside each PE, output channels across columns,
//! feature-map height across rows.

use super::pe::Pe;
use crate::arch::Precision;
use crate::error::{Error, Result};

/// Functional model of one lane's SA core (plus its accumulator banks).
#[derive(Debug, Clone)]
pub struct SaCore {
    tile_r: usize,
    tile_c: usize,
    /// `banks[b][r][c]` — accumulator banks of PEs.
    banks: Vec<Vec<Pe>>,
}

impl SaCore {
    /// Build a core with `n_banks` accumulator banks.
    pub fn new(tile_r: usize, tile_c: usize, n_banks: usize) -> Self {
        SaCore {
            tile_r,
            tile_c,
            banks: vec![vec![Pe::new(); tile_r * tile_c]; n_banks],
        }
    }

    /// Rows of the PE array.
    pub fn tile_r(&self) -> usize {
        self.tile_r
    }

    /// Columns of the PE array.
    pub fn tile_c(&self) -> usize {
        self.tile_c
    }

    /// Number of accumulator banks.
    pub fn n_banks(&self) -> usize {
        self.banks.len()
    }

    fn bank_mut(&mut self, bank: usize) -> Result<&mut Vec<Pe>> {
        let n = self.banks.len();
        self.banks
            .get_mut(bank)
            .ok_or_else(|| Error::sim(format!("acc bank {bank} out of range (n={n})")))
    }

    /// Zero a bank (`vsam.macz` prologue).
    pub fn clear_bank(&mut self, bank: usize) -> Result<()> {
        for pe in self.bank_mut(bank)? {
            pe.clear();
        }
        Ok(())
    }

    /// Zero every accumulator bank (pooled-processor reuse).
    pub fn reset(&mut self) {
        for bank in &mut self.banks {
            for pe in bank.iter_mut() {
                pe.clear();
            }
        }
    }

    /// Stream a tile: `a` is `[tile_r][steps]` unified elements
    /// (given as flat operand arrays, `group` operands per element),
    /// `b` is `[tile_c][steps]`. `a_row_stride_elems` expresses the
    /// windowed (FF) addressing: consecutive rows start `stride` elements
    /// apart inside `a`, enabling overlapping-window reuse without
    /// duplication. Dense layout = stride of `steps`.
    ///
    /// `a` must contain at least `(tile_r-1)*stride + steps` elements'
    /// worth of operands; `b` exactly `tile_c * steps` elements.
    pub fn mac_tile(
        &mut self,
        bank: usize,
        p: Precision,
        a_ops: &[i64],
        a_row_stride_elems: usize,
        b_ops: &[i64],
        steps: usize,
        init: bool,
    ) -> Result<()> {
        let g = p.group();
        let (tile_r, tile_c) = (self.tile_r, self.tile_c);
        let need_a = ((tile_r - 1) * a_row_stride_elems + steps) * g;
        if a_ops.len() < need_a {
            return Err(Error::sim(format!(
                "mac_tile: input matrix too small ({} < {need_a} operands)",
                a_ops.len()
            )));
        }
        if b_ops.len() < tile_c * steps * g {
            return Err(Error::sim(format!(
                "mac_tile: weight matrix too small ({} < {} operands)",
                b_ops.len(),
                tile_c * steps * g
            )));
        }
        if init {
            self.clear_bank(bank)?;
        }
        let pes = self.bank_mut(bank)?;
        for r in 0..tile_r {
            let a_base = r * a_row_stride_elems * g;
            for c in 0..tile_c {
                let pe = &mut pes[r * tile_c + c];
                let b_base = c * steps * g;
                for k in 0..steps {
                    let a_el = &a_ops[a_base + k * g..a_base + (k + 1) * g];
                    let b_el = &b_ops[b_base + k * g..b_base + (k + 1) * g];
                    pe.mac_unified(p, a_el, b_el);
                }
            }
        }
        Ok(())
    }

    /// Raw partials of a bank, row-major `[tile_r][tile_c]` (`vsam.wb`).
    pub fn read_bank(&self, bank: usize) -> Result<Vec<i32>> {
        let pes = self
            .banks
            .get(bank)
            .ok_or_else(|| Error::sim(format!("acc bank {bank} out of range")))?;
        Ok(pes.iter().map(|pe| pe.value()).collect())
    }

    /// Load raw partials into a bank (`vsam.ldacc`).
    pub fn write_bank(&mut self, bank: usize, vals: &[i32]) -> Result<()> {
        let (tile_r, tile_c) = (self.tile_r, self.tile_c);
        if vals.len() != tile_r * tile_c {
            return Err(Error::sim(format!(
                "write_bank: expected {} partials, got {}",
                tile_r * tile_c,
                vals.len()
            )));
        }
        for (pe, &v) in self.bank_mut(bank)?.iter_mut().zip(vals) {
            pe.load(v);
        }
        Ok(())
    }

    /// Drain a bank with requant (`vsam.st`): returns `[tile_r][tile_c]`
    /// requantized outputs.
    pub fn drain_bank(
        &self,
        bank: usize,
        shift: u8,
        relu: bool,
        p: Precision,
    ) -> Result<Vec<i64>> {
        let pes = self
            .banks
            .get(bank)
            .ok_or_else(|| Error::sim(format!("acc bank {bank} out of range")))?;
        Ok(pes.iter().map(|pe| pe.requant(shift, relu, p)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{check, PropConfig};

    /// Naive reference: ACC[r][c] = Σ_k Σ_g A[r][k][g]·B[c][k][g] (mod 2³²).
    fn reference(
        p: Precision,
        a: &[i64],
        stride: usize,
        b: &[i64],
        r_n: usize,
        c_n: usize,
        steps: usize,
    ) -> Vec<i32> {
        let g = p.group();
        let mut out = vec![0i32; r_n * c_n];
        for r in 0..r_n {
            for c in 0..c_n {
                let mut acc = 0i32;
                for k in 0..steps {
                    for gi in 0..g {
                        let av = a[(r * stride + k) * g + gi];
                        let bv = b[(c * steps + k) * g + gi];
                        acc = acc.wrapping_add((av * bv) as i32);
                    }
                }
                out[r * c_n + c] = acc;
            }
        }
        out
    }

    #[test]
    fn dense_tile_matches_reference_property() {
        check(PropConfig::new(100, 0x5AC0), |rng| {
            let p = *rng.pick(&Precision::ALL);
            let (r_n, c_n) = (4usize, 4usize);
            let steps = rng.range_usize(1, 12);
            let g = p.group();
            let a = rng.signed_vec(p.bits(), r_n * steps * g);
            let b = rng.signed_vec(p.bits(), c_n * steps * g);
            let mut core = SaCore::new(r_n, c_n, 2);
            core.mac_tile(1, p, &a, steps, &b, steps, true).map_err(|e| e.to_string())?;
            let got = core.read_bank(1).map_err(|e| e.to_string())?;
            let want = reference(p, &a, steps, &b, r_n, c_n, steps);
            if got != want {
                return Err(format!("{p} steps={steps}: {got:?} != {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn windowed_stride_shares_rows() {
        // stride 1 with steps 3 means row r reads elements r..r+3 — the
        // FF overlapping-window pattern over a 1-D input line.
        let p = Precision::Int16;
        let a: Vec<i64> = (1..=6).collect(); // line of 6 elements
        let b = vec![1i64; 4 * 3]; // 4 cols, weights all 1
        let mut core = SaCore::new(4, 4, 1);
        core.mac_tile(0, p, &a, 1, &b, 3, true).unwrap();
        let got = core.read_bank(0).unwrap();
        // row r computes sum(a[r..r+3]) for every column
        for r in 0..4 {
            let want: i64 = (1 + r as i64) + (2 + r as i64) + (3 + r as i64);
            for c in 0..4 {
                assert_eq!(got[r * 4 + c], want as i32, "r={r} c={c}");
            }
        }
    }

    #[test]
    fn accumulate_continues_without_init() {
        let p = Precision::Int4;
        let g = p.group();
        let a = vec![1i64; 4 * 2 * g];
        let b = vec![1i64; 4 * 2 * g];
        let mut core = SaCore::new(4, 4, 1);
        core.mac_tile(0, p, &a, 2, &b, 2, true).unwrap();
        core.mac_tile(0, p, &a, 2, &b, 2, false).unwrap();
        let got = core.read_bank(0).unwrap();
        assert!(got.iter().all(|&v| v == (2 * 2 * g) as i32));
    }

    #[test]
    fn wb_ldacc_roundtrip() {
        let mut core = SaCore::new(2, 3, 2);
        let vals: Vec<i32> = (0..6).map(|i| i * 1000 - 2500).collect();
        core.write_bank(0, &vals).unwrap();
        assert_eq!(core.read_bank(0).unwrap(), vals);
        assert!(core.write_bank(0, &vals[..5]).is_err());
    }

    #[test]
    fn undersized_operands_rejected() {
        let mut core = SaCore::new(4, 4, 1);
        let p = Precision::Int16;
        assert!(core.mac_tile(0, p, &[1, 2], 4, &[1; 16], 4, true).is_err());
        assert!(core.mac_tile(0, p, &[1; 16], 4, &[1, 2], 4, true).is_err());
        assert!(core.mac_tile(9, p, &[1; 16], 4, &[1; 16], 4, true).is_err());
    }
}
