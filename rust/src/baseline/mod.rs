//! Ara baseline model (under construction).

pub mod ara;

pub use ara::{simulate_layer_ara, AraLayerResult};
