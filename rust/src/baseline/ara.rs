//! Ara baseline model (Perotti et al., ASAP'22 — "A New Ara").
//!
//! The paper compares SPEED against Ara with matched parameters (4 lanes,
//! VLEN = 4096, same clock/technology). Ara computes convolutions with
//! standard RVV code: strip-mined `vle`/`vmacc.vv` loops over an
//! im2col-style traversal. Its structural limits (the three problems the
//! paper's intro lists):
//!
//! 1. **No 4-bit formats** — int formats are 8/16/32/64 (Table I).
//! 2. **Throughput** — one 64-bit SIMD multiplier slice per lane:
//!    `64/SEW` MACs/lane/cycle (vs SPEED's TILE_R×TILE_C×group).
//! 3. **Dataflow** — ordered `VLE` loads cannot broadcast: every lane
//!    fetches its own operands, and without the SAU's windowed address
//!    generator the im2col traversal re-fetches each input row for every
//!    kernel row (K× input traffic), with partial sums held in vector
//!    registers written back per output strip.
//!
//! The model executes the same structural loop nest Ara's conv kernels
//! use and prices it with the same DRAM/issue machinery as the SPEED
//! simulator, calibrated against Ara's published peaks (see
//! `cost::calib`).

use crate::arch::{AraConfig, Precision};
use crate::core::{InstrMix, SimStats};
use crate::cost::perf;
use crate::dataflow::ConvLayer;
use crate::error::{Error, Result};

/// Result of simulating one layer on Ara.
#[derive(Debug, Clone)]
pub struct AraLayerResult {
    /// Total cycles.
    pub cycles: u64,
    /// Useful MACs.
    pub useful_macs: u64,
    /// DRAM bytes read.
    pub dram_read: u64,
    /// DRAM bytes written.
    pub dram_write: u64,
    /// Vector instructions issued (= `vle + vmacc + vse + vsetvli`).
    pub v_instrs: u64,
    /// `vle` input-row loads issued.
    pub vle: u64,
    /// `vmacc.vv` MAC instructions issued.
    pub vmacc: u64,
    /// `vse` output-row stores issued.
    pub vse: u64,
    /// `vsetvli` strip configurations issued.
    pub vsetvli: u64,
    /// Achieved GOPS.
    pub gops: f64,
}

impl AraLayerResult {
    /// Project this result into the sweep engine's unified [`SimStats`]
    /// shape. The mapping is lossless for everything the cost models
    /// consume: `vle`→load, `vmacc`→mac, `vse`→store, `vsetvli`→config,
    /// so `instrs.total()` equals [`AraLayerResult::v_instrs`] and
    /// [`AraLayerResult::from_stats`] round-trips bit-exactly.
    pub fn to_stats(&self) -> SimStats {
        SimStats {
            cycles: self.cycles,
            macs: self.useful_macs,
            useful_macs: self.useful_macs,
            dram_read: self.dram_read,
            dram_write: self.dram_write,
            instrs: InstrMix {
                load: self.vle,
                mac: self.vmacc,
                store: self.vse,
                config: self.vsetvli,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Rebuild an Ara result from the unified [`SimStats`] shape (the
    /// inverse of [`AraLayerResult::to_stats`]). `freq_mhz` must be the
    /// Ara clock the cycles were produced under; the derived `gops` is
    /// bit-identical to what [`simulate_layer_ara`] reported.
    pub fn from_stats(stats: &SimStats, freq_mhz: f64) -> Self {
        AraLayerResult {
            cycles: stats.cycles,
            useful_macs: stats.useful_macs,
            dram_read: stats.dram_read,
            dram_write: stats.dram_write,
            v_instrs: stats.instrs.total(),
            vle: stats.instrs.load,
            vmacc: stats.instrs.mac,
            vse: stats.instrs.store,
            vsetvli: stats.instrs.config,
            gops: perf::gops(2 * stats.useful_macs, stats.cycles, freq_mhz),
        }
    }
}

/// Cycle model for one conv layer on Ara at `p` (8/16-bit only).
///
/// Loop nest modeled (the standard RVV conv kernel, one output-row strip
/// per iteration, vectors along the output width):
///
/// ```text
/// for co in Cout:
///   for oy in Ho:
///     for ci in Cin:
///       for (ky,kx) in K×K:
///         vle input row segment   (ordered, per-lane fetch)
///         vmacc.vv acc, in, w     (w splatted per scalar weight)
///     vse output row
/// ```
///
/// Input rows are reused across `kx` (single load per `(ci, ky)`), but
/// re-fetched for every `(co, ky)` — Ara has no broadcast reuse across
/// output channels, which is exactly the inefficiency the paper's VSALD
/// addresses.
pub fn simulate_layer_ara(cfg: &AraConfig, layer: &ConvLayer, p: Precision) -> Result<AraLayerResult> {
    let macs_per_cycle = cfg.macs_per_cycle(p)? as u64;
    let sew_bytes = (p.bits() / 8) as u64;
    let (ho, wo) = (layer.ho() as u64, layer.wo() as u64);
    let (cin, cout, k) = (layer.cin as u64, layer.cout as u64, layer.k as u64);
    if wo == 0 || ho == 0 {
        return Err(Error::mapping(format!("degenerate layer {layer}")));
    }

    // vector length per strip: whole output row, strip-mined to VLMAX
    let vlmax = cfg.vlmax(p.bits() as usize) as u64;
    let strips_per_row = wo.div_ceil(vlmax);
    let vl = wo.min(vlmax);

    // --- instruction counts ---
    // per (co, oy, ci, ky): 1 vle (input row seg) ; per (…, kx): 1 vmacc
    let vle_count = cout * ho * cin * k * strips_per_row;
    let vmacc_count = cout * ho * cin * k * k * strips_per_row;
    let vse_count = cout * ho * strips_per_row;
    let vsetvli_count = cout * ho * strips_per_row;
    let v_instrs = vle_count + vmacc_count + vse_count + vsetvli_count;

    // --- compute cycles ---
    // each vmacc processes vl elements at (lanes × 64/SEW) MACs/cycle
    let vmacc_cycles = vmacc_count * vl.div_ceil(macs_per_cycle);

    // --- memory traffic ---
    // inputs: row of (vl·S + K−1) values per (co, oy, ci, ky) strip
    let in_row_vals = (vl * layer.stride as u64) + k - 1;
    let dram_read_in = vle_count * in_row_vals * sew_bytes;
    // weights: scalar splats, one fetch per (co, ci, ky, kx) — negligible
    // but counted
    let dram_read_w = cout * cin * k * k * sew_bytes;
    // outputs: one row write per strip (32-bit partials stay in vregs)
    let dram_write = vse_count * vl * sew_bytes;
    let dram_read = dram_read_in + dram_read_w;

    // --- timeline composition ---
    // issue: Ara's in-order front end, `issue_cycles` per vector instr
    let issue_cycles = v_instrs * cfg.issue_cycles;
    // memory: bandwidth-limited streaming
    let mem_cycles = ((dram_read + dram_write) as f64 / cfg.dram_bw_bytes_per_cycle).ceil() as u64;
    // compute, memory and issue overlap; the machine runs at the max,
    // plus a latency term for the non-overlapped load heads per strip.
    let latency_exposed = (cout * ho * strips_per_row) * (cfg.dram_latency_cycles / 8);
    let cycles = vmacc_cycles.max(mem_cycles).max(issue_cycles) + latency_exposed;

    let useful_macs = layer.macs();
    let gops = perf::gops(2 * useful_macs, cycles, cfg.freq_mhz);

    Ok(AraLayerResult {
        cycles,
        useful_macs,
        dram_read,
        dram_write,
        v_instrs,
        vle: vle_count,
        vmacc: vmacc_count,
        vse: vse_count,
        vsetvli: vsetvli_count,
        gops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer3x3() -> ConvLayer {
        ConvLayer::new("t", 64, 64, 56, 56, 3, 1, 1)
    }

    #[test]
    fn int4_rejected() {
        let cfg = AraConfig::default();
        assert!(simulate_layer_ara(&cfg, &layer3x3(), Precision::Int4).is_err());
    }

    #[test]
    fn gops_below_peak() {
        let cfg = AraConfig::default();
        for p in [Precision::Int8, Precision::Int16] {
            let r = simulate_layer_ara(&cfg, &layer3x3(), p).unwrap();
            assert!(r.gops > 0.0);
            assert!(
                r.gops <= cfg.peak_gops(p).unwrap(),
                "{p}: {} > peak {}",
                r.gops,
                cfg.peak_gops(p).unwrap()
            );
        }
    }

    #[test]
    fn int8_faster_than_int16() {
        let cfg = AraConfig::default();
        let r8 = simulate_layer_ara(&cfg, &layer3x3(), Precision::Int8).unwrap();
        let r16 = simulate_layer_ara(&cfg, &layer3x3(), Precision::Int16).unwrap();
        assert!(r8.gops > r16.gops);
    }

    #[test]
    fn stats_projection_round_trips() {
        let cfg = AraConfig::default();
        let r = simulate_layer_ara(&cfg, &layer3x3(), Precision::Int8).unwrap();
        assert_eq!(r.v_instrs, r.vle + r.vmacc + r.vse + r.vsetvli);
        let s = r.to_stats();
        assert_eq!(s.instrs.total(), r.v_instrs);
        let back = AraLayerResult::from_stats(&s, cfg.freq_mhz);
        assert_eq!(back.cycles, r.cycles);
        assert_eq!(back.useful_macs, r.useful_macs);
        assert_eq!(back.dram_read, r.dram_read);
        assert_eq!(back.dram_write, r.dram_write);
        assert_eq!(back.v_instrs, r.v_instrs);
        assert_eq!(back.gops.to_bits(), r.gops.to_bits(), "gops must round-trip bit-exactly");
    }

    #[test]
    fn input_traffic_scales_with_k() {
        let cfg = AraConfig::default();
        let l1 = ConvLayer::new("p", 64, 64, 56, 56, 1, 1, 0);
        let r1 = simulate_layer_ara(&cfg, &l1, Precision::Int8).unwrap();
        let r3 = simulate_layer_ara(&cfg, &layer3x3(), Precision::Int8).unwrap();
        // 3x3 does 9× the MACs but also ~3× the input traffic per MAC
        // structure: traffic ratio must exceed the pure-volume ratio 1.
        assert!(r3.dram_read > 2 * r1.dram_read);
    }
}
