//! ISA-layer contract tests: the encoder and decoder round-trip **every**
//! `Instr` variant (including all `Vsacfg` minor ops, all four `Vsald`
//! distribution modes and all five `Vsam` minor ops) bit-exactly, and the
//! disassembler's syntax is pinned by a golden table.

use speed::arch::Precision;
use speed::isa::{
    decode, disassemble, encode, ElemWidth, Instr, LoadMode, Strategy, VType, Vsacfg, Vsam,
};
use speed::testutil::{check, PropConfig, Prng};

fn reg(rng: &mut Prng) -> u8 {
    rng.range_usize(0, 31) as u8
}

/// Uniformly sample every encodable `Instr` variant with field values
/// spanning each field's full encodable range.
fn arbitrary_instr(rng: &mut Prng) -> Instr {
    let widths = [ElemWidth::E8, ElemWidth::E16, ElemWidth::E32];
    match rng.below(25) {
        0 => Instr::Lui { rd: reg(rng), imm20: rng.range_i64(-(1 << 19), (1 << 19) - 1) as i32 },
        1 => Instr::Addi { rd: reg(rng), rs1: reg(rng), imm12: rng.range_i64(-2048, 2047) as i32 },
        2 => Instr::Slli { rd: reg(rng), rs1: reg(rng), shamt: rng.range_usize(0, 63) as u8 },
        3 => Instr::Add { rd: reg(rng), rs1: reg(rng), rs2: reg(rng) },
        4 => Instr::Vsetvli {
            rd: reg(rng),
            rs1: reg(rng),
            vtype: VType::new(*rng.pick(&[8, 16, 32, 64]), *rng.pick(&[1, 2, 4, 8])).unwrap(),
        },
        5 => Instr::Vle { width: *rng.pick(&widths), vd: reg(rng), rs1: reg(rng) },
        6 => Instr::Vse { width: *rng.pick(&widths), vs3: reg(rng), rs1: reg(rng) },
        7 => Instr::VmaccVv { vd: reg(rng), vs1: reg(rng), vs2: reg(rng) },
        8 => Instr::VaddVv { vd: reg(rng), vs2: reg(rng), vs1: reg(rng) },
        9 => Instr::VmulVv { vd: reg(rng), vs2: reg(rng), vs1: reg(rng) },
        10 => Instr::VsraVi { vd: reg(rng), vs2: reg(rng), uimm: rng.range_usize(0, 31) as u8 },
        11 => Instr::Vsacfg(Vsacfg::Main {
            precision: *rng.pick(&Precision::ALL),
            strategy: Strategy::decode(rng.below(2) as u32),
            tile_h: rng.range_usize(0, 63) as u8,
        }),
        12 => Instr::Vsacfg(Vsacfg::RowStride {
            rs1: reg(rng),
            aincr: rng.range_usize(0, 4095) as u16,
        }),
        13 => Instr::Vsacfg(Vsacfg::OutStride { rs1: reg(rng) }),
        14 => Instr::Vsacfg(Vsacfg::Shift { uimm5: rng.range_usize(0, 31) as u8 }),
        15 => Instr::Vsacfg(Vsacfg::AOffset { rs1: reg(rng) }),
        16 => Instr::Vsacfg(Vsacfg::WOffset { rs1: reg(rng) }),
        17 => Instr::Vsacfg(Vsacfg::CStride { rs1: reg(rng) }),
        18 => Instr::Vsacfg(Vsacfg::RunCfg {
            rs1: reg(rng),
            runlen: rng.range_usize(0, 4095) as u16,
        }),
        19 => {
            let stride = rng.range_usize(0, 4095) as u16;
            let mode = match rng.below(4) {
                0 => LoadMode::Ordered,
                1 => LoadMode::Broadcast,
                2 => LoadMode::OrderedStrided(stride),
                _ => LoadMode::BroadcastStrided(stride),
            };
            Instr::Vsald { vd: reg(rng), rs1: reg(rng), mode }
        }
        20 => Instr::Vsam(Vsam::MacZ {
            acc: reg(rng),
            vs1: reg(rng),
            vs2: reg(rng),
            bump: rng.below(2) == 1,
        }),
        21 => Instr::Vsam(Vsam::Mac {
            acc: reg(rng),
            vs1: reg(rng),
            vs2: reg(rng),
            bump: rng.below(2) == 1,
        }),
        22 => Instr::Vsam(Vsam::Wb { vd: reg(rng), acc: reg(rng), bump: rng.below(2) == 1 }),
        23 => Instr::Vsam(Vsam::LdAcc { acc: reg(rng), vs1: reg(rng), bump: rng.below(2) == 1 }),
        _ => Instr::Vsam(Vsam::St { acc: reg(rng), rs1: reg(rng), relu: rng.below(2) == 1 }),
    }
}

#[test]
fn encode_decode_encode_roundtrips_every_variant() {
    check(PropConfig::new(4000, 0x150C), |rng| {
        let i = arbitrary_instr(rng);
        let w = encode(&i);
        let back = decode(w).map_err(|e| e.to_string())?;
        if back != i {
            return Err(format!("decode: {i:?} -> {w:#010x} -> {back:?}"));
        }
        let w2 = encode(&back);
        if w2 != w {
            return Err(format!("re-encode: {i:?} -> {w:#010x} -> {w2:#010x}"));
        }
        Ok(())
    });
}

#[test]
fn disasm_golden() {
    let golden: Vec<(Instr, &str)> = vec![
        (Instr::Lui { rd: 10, imm20: 0x12345 }, "lui a0, 0x12345"),
        (Instr::Addi { rd: 2, rs1: 2, imm12: -16 }, "addi sp, sp, -16"),
        (Instr::Slli { rd: 11, rs1: 10, shamt: 4 }, "slli a1, a0, 4"),
        (Instr::Add { rd: 12, rs1: 10, rs2: 11 }, "add a2, a0, a1"),
        (
            Instr::Vsetvli { rd: 5, rs1: 10, vtype: VType::new(32, 4).unwrap() },
            "vsetvli t0, a0, e32, m4",
        ),
        (Instr::Vle { width: ElemWidth::E16, vd: 2, rs1: 10 }, "vle16.v v2, (a0)"),
        (Instr::Vse { width: ElemWidth::E32, vs3: 2, rs1: 11 }, "vse32.v v2, (a1)"),
        (Instr::VmaccVv { vd: 4, vs1: 5, vs2: 6 }, "vmacc.vv v4, v5, v6"),
        (Instr::VaddVv { vd: 1, vs2: 2, vs1: 3 }, "vadd.vv v1, v2, v3"),
        (Instr::VmulVv { vd: 1, vs2: 2, vs1: 3 }, "vmul.vv v1, v2, v3"),
        (Instr::VsraVi { vd: 1, vs2: 2, uimm: 15 }, "vsra.vi v1, v2, 15"),
        (
            Instr::Vsacfg(Vsacfg::Main {
                precision: Precision::Int4,
                strategy: Strategy::FeatureFirst,
                tile_h: 6,
            }),
            "vsacfg e4, ff, th6",
        ),
        (
            Instr::Vsacfg(Vsacfg::Main {
                precision: Precision::Int16,
                strategy: Strategy::ChannelFirst,
                tile_h: 4,
            }),
            "vsacfg e16, cf, th4",
        ),
        (Instr::Vsacfg(Vsacfg::RowStride { rs1: 6, aincr: 64 }), "vsacfg.rowstride t1, 64"),
        (Instr::Vsacfg(Vsacfg::OutStride { rs1: 7 }), "vsacfg.outstride t2"),
        (Instr::Vsacfg(Vsacfg::Shift { uimm5: 11 }), "vsacfg.shift 11"),
        (Instr::Vsacfg(Vsacfg::AOffset { rs1: 10 }), "vsacfg.aoffset a0"),
        (Instr::Vsacfg(Vsacfg::WOffset { rs1: 11 }), "vsacfg.woffset a1"),
        (Instr::Vsacfg(Vsacfg::CStride { rs1: 13 }), "vsacfg.cstride a3"),
        (Instr::Vsacfg(Vsacfg::RunCfg { rs1: 30, runlen: 9 }), "vsacfg.runcfg t5, 9"),
        (Instr::Vsald { vd: 0, rs1: 13, mode: LoadMode::Broadcast }, "vsald.b v0, (a3)"),
        (Instr::Vsald { vd: 8, rs1: 14, mode: LoadMode::Ordered }, "vsald.o v8, (a4)"),
        (
            Instr::Vsald { vd: 2, rs1: 10, mode: LoadMode::BroadcastStrided(3) },
            "vsald.bs v2, (a0), 3",
        ),
        (
            Instr::Vsald { vd: 8, rs1: 14, mode: LoadMode::OrderedStrided(5) },
            "vsald.os v8, (a4), 5",
        ),
        (
            Instr::Vsam(Vsam::MacZ { acc: 0, vs1: 0, vs2: 8, bump: false }),
            "vsam.macz acc0, v0, v8",
        ),
        (
            Instr::Vsam(Vsam::MacZ { acc: 1, vs1: 0, vs2: 8, bump: true }),
            "vsam.macz.b acc1, v0, v8",
        ),
        (Instr::Vsam(Vsam::Mac { acc: 3, vs1: 0, vs2: 8, bump: true }), "vsam.mac.b acc3, v0, v8"),
        (Instr::Vsam(Vsam::Wb { vd: 16, acc: 2, bump: false }), "vsam.wb v16, acc2"),
        (Instr::Vsam(Vsam::LdAcc { acc: 2, vs1: 16, bump: true }), "vsam.ldacc.b acc2, v16"),
        (Instr::Vsam(Vsam::St { acc: 1, rs1: 15, relu: false }), "vsam.st acc1, (a5)"),
        (Instr::Vsam(Vsam::St { acc: 0, rs1: 16, relu: true }), "vsam.st.relu acc0, (a6)"),
    ];
    for (i, want) in &golden {
        assert_eq!(&disassemble(i), want, "disasm golden mismatch for {i:?}");
        // and the golden instructions round-trip through the encoder too
        assert_eq!(decode(encode(i)).unwrap(), *i, "encode/decode of {i:?}");
    }
}
