//! Property tests over the whole simulation stack: invariants that must
//! hold for *any* layer/precision/strategy, checked over randomized
//! workloads (deterministic PRNG; failures print a replayable seed).

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::{run_functional_conv, simulate_layer};
use speed::cost::roofline_gops;
use speed::dataflow::{compile_conv, ConvLayer, Strategy};
use speed::mem::tensor::conv2d_ref;
use speed::mem::Tensor;
use speed::testutil::{check, PropConfig, Prng};

fn random_layer(rng: &mut Prng) -> ConvLayer {
    let k = *rng.pick(&[1usize, 3, 5]);
    let stride = *rng.pick(&[1usize, 2]);
    let hw = rng.range_usize(k.max(4), 20);
    ConvLayer::new(
        "prop",
        rng.range_usize(1, 40),
        rng.range_usize(1, 40),
        hw,
        hw,
        k,
        stride,
        k / 2,
    )
}

#[test]
fn simulator_never_beats_the_roofline() {
    let cfg = SpeedConfig::default();
    check(PropConfig::new(40, 0x0F1), |rng| {
        let layer = random_layer(rng);
        let p = *rng.pick(&Precision::ALL);
        let s = *rng.pick(&[Strategy::FeatureFirst, Strategy::ChannelFirst]);
        let r = simulate_layer(&cfg, &layer, p, s).map_err(|e| e.to_string())?;
        let g = r.gops(&cfg);
        let bound = cfg.peak_gops(p); // compute roof (traffic may be >1 pass)
        if g > bound * 1.0001 {
            return Err(format!("{layer} {p} {s}: {g:.2} GOPS beats peak {bound:.2}"));
        }
        let roof = roofline_gops(&cfg, &layer, p);
        // the analytical roofline assumes minimal traffic; the simulator
        // always moves at least that much data, so it may only beat the
        // *bandwidth* roof if it is compute-bound below peak — never both.
        if g > roof * 1.05 && g > bound * 0.99 {
            return Err(format!("{layer} {p} {s}: {g:.2} > roofline {roof:.2} at peak"));
        }
        Ok(())
    });
}

#[test]
fn utilization_is_a_fraction_and_work_is_conserved() {
    let cfg = SpeedConfig::default();
    check(PropConfig::new(40, 0x0F2), |rng| {
        let layer = random_layer(rng);
        let p = *rng.pick(&Precision::ALL);
        let s = *rng.pick(&[Strategy::FeatureFirst, Strategy::ChannelFirst]);
        let r = simulate_layer(&cfg, &layer, p, s).map_err(|e| e.to_string())?;
        let u = r.utilization(&cfg);
        if !(u > 0.0 && u <= 1.0) {
            return Err(format!("{layer} {p} {s}: utilization {u}"));
        }
        // hardware MACs include tail/padding work, never less than useful
        if r.stats.macs < r.useful_macs {
            return Err(format!(
                "{layer} {p} {s}: hw macs {} < useful {}",
                r.stats.macs, r.useful_macs
            ));
        }
        // weights must be fetched at least once
        let cc = compile_conv(&cfg, &layer, p, s, 0, false).map_err(|e| e.to_string())?;
        if r.stats.dram_read < cc.plan.weight_image_bytes() as u64 {
            return Err(format!("{layer} {p} {s}: weights not fully fetched"));
        }
        Ok(())
    });
}

#[test]
fn timing_mode_equals_functional_mode_cycles() {
    // Both modes share one scheduler; cycle counts must be identical.
    let cfg = SpeedConfig::default();
    check(PropConfig::new(12, 0x0F3), |rng| {
        let k = *rng.pick(&[1usize, 3]);
        let hw = rng.range_usize(k.max(4), 10);
        let layer = ConvLayer::new(
            "tf",
            rng.range_usize(1, 12),
            rng.range_usize(1, 12),
            hw,
            hw,
            k,
            1,
            k / 2,
        );
        let p = *rng.pick(&Precision::ALL);
        let s = *rng.pick(&[Strategy::FeatureFirst, Strategy::ChannelFirst]);
        // timing mode
        let t = simulate_layer(&cfg, &layer, p, s).map_err(|e| e.to_string())?;
        // functional mode (run_functional_conv uses ExecMode::Functional
        // internally but does not report stats; re-run via processor)
        let cc = compile_conv(&cfg, &layer, p, s, 3, false).map_err(|e| e.to_string())?;
        let mut proc = speed::core::Processor::new(
            cfg.clone(),
            cc.dram_bytes,
            speed::core::ExecMode::Functional,
        )
        .map_err(|e| e.to_string())?;
        proc.run(&cc.program).map_err(|e| e.to_string())?;
        if proc.stats().cycles != t.cycles {
            return Err(format!(
                "{layer} {p} {s}: functional {} != timing {} cycles",
                proc.stats().cycles,
                t.cycles
            ));
        }
        Ok(())
    });
}

#[test]
fn functional_conv_matches_reference_randomized() {
    // Broad random cross-check of the whole functional path (the
    // targeted per-feature versions live in coordinator::runner tests).
    let cfg = SpeedConfig::default();
    check(PropConfig::new(10, 0x0F4), |rng| {
        let k = *rng.pick(&[1usize, 3]);
        let stride = *rng.pick(&[1usize, 2]);
        let hw = rng.range_usize(k.max(4), 11);
        let layer = ConvLayer::new(
            "fr",
            rng.range_usize(1, 10),
            rng.range_usize(1, 10),
            hw,
            hw,
            k,
            stride,
            k / 2,
        );
        let p = *rng.pick(&Precision::ALL);
        let s = *rng.pick(&[Strategy::FeatureFirst, Strategy::ChannelFirst]);
        let shift = rng.range_usize(0, 8) as u8;
        let relu = rng.below(2) == 1;
        let input = Tensor::random(&[layer.cin, layer.h, layer.w], p, rng);
        let weights = Tensor::random(&[layer.cout, layer.cin, layer.k, layer.k], p, rng);
        let got = run_functional_conv(&cfg, &layer, p, s, &input, &weights, shift, relu)
            .map_err(|e| e.to_string())?;
        let want = conv2d_ref(&input, &weights, p, layer.stride, layer.pad, shift, relu);
        if got.data != want.data {
            return Err(format!("{layer} {p} {s} shift={shift} relu={relu}: mismatch"));
        }
        Ok(())
    });
}

#[test]
fn config_scaling_directions() {
    // More compute → no slower; more bandwidth → no slower.
    let layer = ConvLayer::new("s", 32, 32, 28, 28, 3, 1, 1);
    let p = Precision::Int8;
    let base = SpeedConfig::default();
    let r0 = simulate_layer(&base, &layer, p, Strategy::Mixed).unwrap();
    let mut big = base.clone();
    big.tile_r = 8;
    big.tile_c = 8;
    let r1 = simulate_layer(&big, &layer, p, Strategy::Mixed).unwrap();
    assert!(r1.cycles <= r0.cycles, "4x PEs must not slow down");
    let mut bw = base.clone();
    bw.dram_bw_bytes_per_cycle = 64.0;
    let r2 = simulate_layer(&bw, &layer, p, Strategy::Mixed).unwrap();
    assert!(r2.cycles <= r0.cycles, "4x bandwidth must not slow down");
}
