//! Backend-parity contract: routing the Ara baseline and the golden
//! functional checks through the sweep engine's backend axis must
//! reproduce the old serial compositions **bit-identically**.
//!
//! - `AraAnalytic` engine cells == `simulate_layer_ara` (the serial
//!   model), layer by layer, over the paper's full benchmark grid;
//! - the Fig. 3 driver's Ara column == the pre-refactor serial-tail
//!   arithmetic, recomposed here from first principles;
//! - `GoldenFunctional` batch verification == one-off
//!   `run_functional_conv` calls on the same operands.

use std::sync::Arc;

use speed::arch::{AraConfig, Precision, SpeedConfig};
use speed::baseline::{simulate_layer_ara, AraLayerResult};
use speed::coordinator::backend::{AraAnalytic, GoldenFunctional, WorkerSlot};
use speed::coordinator::experiments::{run_fig3, run_fig4_with, run_table1_with};
use speed::coordinator::run_functional_conv;
use speed::coordinator::sweep::{SweepEngine, SweepSpec};
use speed::cost::ara_area_mm2;
use speed::dataflow::{ConvLayer, Strategy};
use speed::models::all_models;

/// The pre-refactor serial network-efficiency arithmetic, verbatim.
fn serial_ara_network_eff(results: &[AraLayerResult], ara: &AraConfig) -> f64 {
    let ops: u64 = results.iter().map(|r| 2 * r.useful_macs).sum();
    let cycles: u64 = results.iter().map(|r| r.cycles).sum();
    let secs = cycles as f64 / (ara.freq_mhz * 1e6);
    ops as f64 / secs / 1e9 / ara_area_mm2()
}

#[test]
fn ara_engine_cells_match_serial_model_over_benchmark_grid() {
    // The Ara model is analytic, so the whole four-network grid is
    // cheap; run it through the engine (Ara backend only) and compare
    // every cell against the direct serial call.
    let cfg = SpeedConfig::default();
    let ara_cfg = AraConfig::default();
    let spec = SweepSpec::benchmark_suite(&cfg)
        .backends(vec![Arc::new(AraAnalytic::new(ara_cfg.clone()))]);
    let out = SweepEngine::new().run(&spec).unwrap();
    for (mi, model) in all_models().iter().enumerate() {
        for (pi, p) in [Precision::Int16, Precision::Int8, Precision::Int4]
            .into_iter()
            .enumerate()
        {
            let block = out.block(0, 0, mi, pi, 0);
            if p == Precision::Int4 {
                assert!(block.is_empty(), "{}: Ara has no 4-bit cells", model.name);
                continue;
            }
            assert_eq!(block.len(), model.layers.len(), "{} @{p}", model.name);
            for (r, layer) in block.iter().zip(&model.layers) {
                let want = simulate_layer_ara(&ara_cfg, layer, p).unwrap();
                assert_eq!(r.cycles, want.cycles, "{layer} @{p}");
                assert_eq!(r.useful_macs, want.useful_macs, "{layer} @{p}");
                assert_eq!(r.stats, want.to_stats(), "{layer} @{p}");
                let back = AraLayerResult::from_stats(&r.stats, ara_cfg.freq_mhz);
                assert_eq!(
                    back.gops.to_bits(),
                    want.gops.to_bits(),
                    "{layer} @{p}: GOPS must be bit-identical"
                );
                assert_eq!(back.v_instrs, want.v_instrs, "{layer} @{p}");
                assert_eq!(back.dram_read, want.dram_read, "{layer} @{p}");
                assert_eq!(back.dram_write, want.dram_write, "{layer} @{p}");
            }
        }
    }
}

#[test]
fn fig3_ara_column_matches_pre_refactor_serial_tail() {
    // run_fig3 now schedules Ara through the engine; its Ara column and
    // network-level efficiency must equal the old serial-tail
    // arithmetic exactly, bit for bit.
    let cfg = SpeedConfig::default();
    let f3 = run_fig3(&cfg).unwrap();
    let ara_cfg = AraConfig::default();
    let model = all_models().into_iter().find(|m| m.name == "GoogLeNet").unwrap();
    assert_eq!(f3.rows.len(), model.layers.len());
    let serial: Vec<AraLayerResult> = model
        .layers
        .iter()
        .map(|l| simulate_layer_ara(&ara_cfg, l, Precision::Int16).unwrap())
        .collect();
    for (row, want) in f3.rows.iter().zip(&serial) {
        let old = want.gops / ara_area_mm2();
        assert_eq!(row.ara.to_bits(), old.to_bits(), "layer {}", row.layer);
    }
    let old_eff = serial_ara_network_eff(&serial, &ara_cfg);
    assert_eq!(f3.eff_ara.to_bits(), old_eff.to_bits(), "network-level Ara efficiency");
    // The mixed-over-ara headline derives from it unchanged.
    assert_eq!(
        f3.mixed_over_ara().to_bits(),
        (f3.eff_mixed / old_eff).to_bits()
    );
}

#[test]
#[ignore = "full benchmark grid (speed + ara backends) — minutes in a debug build; run with --ignored"]
fn fig4_and_table1_ara_columns_match_pre_refactor_serial_tails() {
    let cfg = SpeedConfig::default();
    let ara_cfg = AraConfig::default();
    let mut engine = SweepEngine::new();
    let f4 = run_fig4_with(&mut engine, &cfg).unwrap();
    let t1 = run_table1_with(&mut engine, &cfg).unwrap();
    // Fig. 4: per (model, precision) Ara network efficiency.
    for model in all_models() {
        for p in [Precision::Int16, Precision::Int8, Precision::Int4] {
            let cell = f4
                .cells
                .iter()
                .find(|c| c.model == model.name && c.precision == p)
                .unwrap();
            if p == Precision::Int4 {
                assert!(cell.ara_eff.is_none());
                continue;
            }
            let serial: Vec<AraLayerResult> = model
                .layers
                .iter()
                .map(|l| simulate_layer_ara(&ara_cfg, l, p).unwrap())
                .collect();
            let old = serial_ara_network_eff(&serial, &ara_cfg);
            assert_eq!(cell.ara_eff.unwrap().to_bits(), old.to_bits(), "{} @{p}", model.name);
        }
    }
    // Table I: the serial peak search, verbatim.
    for (i, p) in [Precision::Int16, Precision::Int8].into_iter().enumerate() {
        let mut best: Option<(f64, String)> = None;
        for model in all_models() {
            for layer in &model.layers {
                let r = simulate_layer_ara(&ara_cfg, layer, p).unwrap();
                if best.as_ref().map(|(bg, _)| r.gops > *bg).unwrap_or(true) {
                    best = Some((r.gops, layer.name.clone()));
                }
            }
        }
        let (g, name) = best.unwrap();
        assert_eq!(t1.ara[i].peak_gops.to_bits(), g.to_bits(), "@{p}");
        assert_eq!(t1.ara[i].peak_layer, name, "@{p}");
    }
}

fn verification_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("c3", 8, 16, 10, 10, 3, 1, 1),
        ConvLayer::new("pw", 16, 8, 6, 6, 1, 1, 0),
        ConvLayer::new("s2", 8, 8, 11, 11, 3, 2, 1),
        ConvLayer::new("odd", 5, 9, 9, 9, 3, 1, 1),
    ]
}

#[test]
fn golden_backend_agrees_with_run_functional_conv() {
    // Cell by cell: the batch verifier's output tensor equals a direct
    // run_functional_conv call on the same deterministic operands.
    let cfg = SpeedConfig::default();
    let backend = GoldenFunctional::default();
    let mut slot = WorkerSlot::default();
    for layer in verification_layers() {
        for p in [Precision::Int8, Precision::Int16] {
            for s in [Strategy::FeatureFirst, Strategy::ChannelFirst] {
                let (input, weights) = backend.operands(&layer, p);
                let want = run_functional_conv(
                    &cfg,
                    &layer,
                    p,
                    s,
                    &input,
                    &weights,
                    backend.shift,
                    backend.relu,
                )
                .unwrap();
                let (got, stats) =
                    backend.verify_layer(&mut slot, &cfg, &layer, p, s).unwrap();
                assert_eq!(got.shape, want.shape, "{layer} @{p} [{s}]");
                assert_eq!(got.data, want.data, "{layer} @{p} [{s}]");
                assert!(stats.cycles > 0);
            }
        }
    }
}

#[test]
fn verification_suite_batches_golden_checks_through_engine() {
    let cfg = SpeedConfig::default();
    let spec = SweepSpec::verification_suite(&cfg).threads(2);
    let engine = SweepEngine::new();
    let out = engine.run(&spec).unwrap();
    // 4 distinct shapes × 3 precisions × 2 concrete strategies.
    assert_eq!(out.results.len(), spec.n_jobs());
    assert_eq!(out.executed_sims, 24);
    assert!(out.results.iter().all(|r| r.cycles > 0));
    // A verified cell is an ordinary memoized result: the warm rerun is
    // pure cache and bit-identical.
    let warm = engine.run(&spec).unwrap();
    assert_eq!(warm.executed_sims, 0);
    assert_eq!(warm.results, out.results);
}
