//! Integration check of the paper's core dataflow claim (Sec. II-C /
//! Fig. 3): FF wins large kernels, CF wins 1×1, and Mixed dominates both.

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::simulate_layer;
use speed::dataflow::{ConvLayer, Strategy};

#[test]
fn ff_wins_3x3_cf_wins_1x1_across_precisions() {
    let cfg = SpeedConfig::default();
    let conv3 = ConvLayer::new("r3", 64, 64, 56, 56, 3, 1, 1);
    let pw = ConvLayer::new("pw", 128, 128, 28, 28, 1, 1, 0);
    for p in [Precision::Int16, Precision::Int8, Precision::Int4] {
        let ff3 = simulate_layer(&cfg, &conv3, p, Strategy::FeatureFirst).unwrap();
        let cf3 = simulate_layer(&cfg, &conv3, p, Strategy::ChannelFirst).unwrap();
        assert!(
            ff3.cycles < cf3.cycles,
            "{p}: FF should win 3x3 ({} vs {})",
            ff3.cycles,
            cf3.cycles
        );
        let ff1 = simulate_layer(&cfg, &pw, p, Strategy::FeatureFirst).unwrap();
        let cf1 = simulate_layer(&cfg, &pw, p, Strategy::ChannelFirst).unwrap();
        assert!(
            cf1.cycles < ff1.cycles,
            "{p}: CF should win 1x1 ({} vs {})",
            cf1.cycles,
            ff1.cycles
        );
    }
}

#[test]
fn larger_kernels_reach_higher_efficiency() {
    // Fig. 3 observation: "with larger convolution kernel sizes, the
    // area efficiency improves" (more reuse per fetched byte).
    let cfg = SpeedConfig::default();
    let mk = |k: usize| ConvLayer::new("k", 64, 64, 28, 28, k, 1, k / 2);
    let g3 = simulate_layer(&cfg, &mk(3), Precision::Int16, Strategy::Mixed)
        .unwrap();
    let g1 = simulate_layer(&cfg, &mk(1), Precision::Int16, Strategy::Mixed)
        .unwrap();
    assert!(g3.gops(&cfg) > g1.gops(&cfg));
}
