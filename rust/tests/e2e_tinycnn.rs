//! End-to-end integration test: the multi-precision TinyCNN through the
//! functional simulator (layer by layer, host DMA between layers) must be
//! bit-exact with the single AOT-compiled XLA golden network.
//! (The runnable version with reporting lives in examples/e2e_squeezenet.)

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::run_functional_conv;
use speed::dataflow::{ConvLayer, Strategy};
use speed::mem::Tensor;
use speed::runtime::{PjrtRuntime, TinycnnGolden};
use speed::testutil::Prng;

struct Spec(&'static str, usize, usize, usize, usize, usize, Precision, u8, bool);

// Mirrors python/compile/model.py::TINYCNN_SPECS.
const TINYCNN: [Spec; 4] = [
    Spec("conv1", 3, 8, 3, 1, 1, Precision::Int4, 4, true),
    Spec("conv2", 8, 16, 3, 2, 1, Precision::Int8, 6, true),
    Spec("conv3", 16, 16, 3, 1, 1, Precision::Int16, 9, true),
    Spec("head", 16, 10, 1, 1, 0, Precision::Int16, 12, false),
];

#[test]
fn tinycnn_simulator_matches_xla_golden_bit_exactly() {
    if !cfg!(all(feature = "xla", xla_vendored)) {
        eprintln!("SKIP: no XLA client in this build — PJRT runtime is a stub");
        return;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("tinycnn.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let cfg = SpeedConfig::default();
    for seed in [1u64, 42, 0xDEAD] {
        let mut rng = Prng::new(seed);
        let input = Tensor::random(&[3, 16, 16], Precision::Int4, &mut rng);
        let weights: Vec<Tensor> = TINYCNN
            .iter()
            .map(|s| Tensor::random(&[s.2, s.1, s.3, s.3], s.6, &mut rng))
            .collect();
        let mut rt = PjrtRuntime::new(&dir).unwrap();
        let golden = TinycnnGolden::new(&mut rt).run(&input, &weights).unwrap();

        // alternate strategies across layers to exercise both paths
        let mut act = input;
        for (i, (s, w)) in TINYCNN.iter().zip(&weights).enumerate() {
            let layer =
                ConvLayer::new(s.0, s.1, s.2, act.shape[1], act.shape[2], s.3, s.4, s.5);
            let strat = if i % 2 == 0 { Strategy::ChannelFirst } else { Strategy::FeatureFirst };
            act = run_functional_conv(&cfg, &layer, s.6, strat, &act, w, s.7, s.8).unwrap();
        }
        assert_eq!(act.shape, golden.shape, "seed {seed}");
        assert_eq!(act.data, golden.data, "seed {seed}: simulator != XLA golden");
    }
}
