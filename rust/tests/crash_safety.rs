//! Crash safety end to end: the `SPEEDSWJ` write-ahead journal, atomic
//! snapshot saves and the deterministic `faultline` fault-injection
//! layer, exercised through the same public surfaces the CLI uses.
//!
//! Every test takes one file-wide lock: fault plans are process-global
//! (exactly like the `SPEED_FAULT_PLAN` env var they model), so tests
//! must not interleave — a plan installed by one test must never be
//! consumed by another's persist or serve traffic. The lock's guard
//! also clears any installed plan on drop, panic included, so no test
//! can leak triggers into the rest of the binary.

use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::faultline;
use speed::coordinator::fleet::{fleet_summary_line, node_line, run_fleet, FleetOptions};
use speed::coordinator::serve::{self, Request, ServeLimits, ServeShared};
use speed::coordinator::sweep::{SweepEngine, SweepSpec};
use speed::dataflow::Strategy;

static GLOBAL: Mutex<()> = Mutex::new(());

/// File-wide serialization + fault-plan hygiene (see module doc).
struct TestLock {
    _guard: MutexGuard<'static, ()>,
}

impl TestLock {
    fn take() -> TestLock {
        TestLock { _guard: GLOBAL.lock().unwrap_or_else(|p| p.into_inner()) }
    }
}

impl Drop for TestLock {
    fn drop(&mut self) {
        faultline::clear();
    }
}

fn tmp_path(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let n = N.fetch_add(1, Ordering::SeqCst);
    std::env::temp_dir().join(format!("speed-crash-{}-{n}-{tag}", std::process::id()))
}

fn unlimited() -> ServeLimits {
    ServeLimits { max_connections: 0, max_concurrent_sweeps: 0, idle_timeout_secs: 0 }
}

/// One cold simulation: the smallest real workload that populates the
/// memo/delta/summary caches.
fn single_cell_request(id: u64) -> Request {
    Request {
        id,
        network: "SqueezeNet".into(),
        layers: Some(vec![1]),
        precisions: vec![Precision::Int8],
        strategies: vec![Strategy::FeatureFirst],
        threads: Some(1),
        ..Default::default()
    }
}

/// The grid the fleet tests distribute: 3 single-cell items.
fn grid_request(id: u64) -> Request {
    Request {
        id,
        network: "SqueezeNet".into(),
        layers: Some(vec![1, 2, 3]),
        precisions: vec![Precision::Int8],
        strategies: vec![Strategy::FeatureFirst],
        threads: Some(1),
        ..Default::default()
    }
}

fn spec_of(req: &Request) -> SweepSpec {
    req.to_spec(&SpeedConfig::default()).expect("valid request")
}

fn field_u64(line: &str, key: &str) -> u64 {
    for (k, v) in serve::parse_record(line).expect("line parses") {
        if k == key {
            if let serve::Value::Int(n) = v {
                return n;
            }
            panic!("field `{key}` is not an int in {line}");
        }
    }
    panic!("missing field `{key}` in {line}");
}

/// Reference run: one local engine answering `req` over the serve
/// layer. Returns (block lines, executed sims).
fn local_reference(req: &Request) -> (Vec<String>, u64) {
    let shared =
        ServeShared::new(Arc::new(SweepEngine::new()), SpeedConfig::default(), unlimited());
    let input = format!("{}\n", req.to_line());
    let mut out: Vec<u8> = Vec::new();
    let stats = serve::serve_lines(&shared, BufReader::new(input.as_bytes()), &mut out);
    assert_eq!(stats.errors, 0);
    let lines: Vec<String> =
        String::from_utf8(out).expect("utf-8").lines().map(String::from).collect();
    let (summary, blocks) = lines.split_last().expect("summary line");
    assert!(summary.contains("\"type\":\"summary\""), "{summary}");
    (blocks.to_vec(), field_u64(summary, "sims"))
}

/// One in-process worker node: its own engine behind the real TCP
/// accept loop.
struct Node {
    addr: String,
    shutdown: Arc<AtomicBool>,
    handle: thread::JoinHandle<serve::TcpReport>,
}

fn spawn_node() -> Node {
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        unlimited(),
    ));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr").to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    let handle = {
        let shared = Arc::clone(&shared);
        let shutdown = Arc::clone(&shutdown);
        thread::spawn(move || {
            serve::run_tcp(&shared, listener, None, 0, &shutdown).expect("run_tcp")
        })
    };
    Node { addr, shutdown, handle }
}

impl Node {
    fn stop(self) -> serve::TcpReport {
        self.shutdown.store(true, Ordering::SeqCst);
        self.handle.join().expect("node thread")
    }
}

/// An address nothing listens on (bind, learn the port, close).
fn dead_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").expect("bind");
    l.local_addr().expect("addr").to_string()
}

// ---------------------------------------------------------------------------
// Persist fuzzing: decode never panics, merges are all-or-nothing
// ---------------------------------------------------------------------------

#[test]
fn persist_load_survives_every_truncation_and_bit_flip() {
    let _lock = TestLock::take();
    let blob = {
        let engine = SweepEngine::new();
        engine.run(&spec_of(&single_cell_request(1))).expect("seed run");
        engine.serialize_cache()
    };
    let full = SweepEngine::new();
    let n_full = full.load_cache_bytes(&blob).expect("pristine blob loads");
    assert!(n_full >= 1);
    let loaded_sims = full.cached_sims();

    // Every truncation point: never a panic, and a rejected blob must
    // merge nothing (all-or-nothing, exactly like `cache_import`).
    for cut in 0..blob.len() {
        let engine = SweepEngine::new();
        match engine.load_cache_bytes(&blob[..cut]) {
            Ok(_) => assert_eq!(
                engine.cached_sims(),
                loaded_sims,
                "a prefix of {cut} bytes claimed a full merge",
            ),
            Err(_) => assert_eq!(
                engine.cached_sims(),
                0,
                "a rejected {cut}-byte prefix half-merged into the cache",
            ),
        }
    }

    // Every single-bit flip at every offset: same contract.
    for i in 0..blob.len() {
        for bit in 0..8 {
            let mut corrupt = blob.clone();
            corrupt[i] ^= 1 << bit;
            let engine = SweepEngine::new();
            if engine.load_cache_bytes(&corrupt).is_err() {
                assert_eq!(
                    engine.cached_sims(),
                    0,
                    "rejected flip at byte {i} bit {bit} half-merged",
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Atomic snapshot saves under injected torn writes
// ---------------------------------------------------------------------------

#[test]
fn torn_snapshot_write_leaves_the_previous_snapshot_intact() {
    let _lock = TestLock::take();
    let path = tmp_path("torn-save.swc");
    let engine = SweepEngine::new();
    engine.run(&spec_of(&single_cell_request(1))).expect("seed run");
    engine.save_cache(&path).expect("clean save");
    let v1 = fs::read(&path).expect("snapshot exists");

    // First write to the `persist.write` site tears mid-blob: the tmp
    // sibling dies, the rename never happens, the old snapshot stays.
    faultline::install("persist.write:torn@1").expect("valid plan");
    engine.save_cache(&path).expect_err("torn write must surface as an error");
    faultline::clear();
    assert_eq!(fs::read(&path).expect("still there"), v1, "old snapshot must be intact");

    // With the plan cleared the same engine saves fine, and the result
    // loads warm.
    engine.save_cache(&path).expect("save after fault clears");
    let fresh = SweepEngine::new();
    assert!(fresh.load_cache(&path).expect("reload") >= 1);
    assert_eq!(fresh.run(&spec_of(&single_cell_request(2))).expect("warm").executed_sims, 0);
    let _ = fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// Engine journal: warm restart, snapshot interplay, compaction
// ---------------------------------------------------------------------------

#[test]
fn journal_replays_a_killed_engines_results_bit_identically() {
    let _lock = TestLock::take();
    let jpath = tmp_path("engine.swj");
    let snap = tmp_path("engine.swc");
    let spec = spec_of(&single_cell_request(1));

    // "Crash": engine A journals its run and is dropped without ever
    // calling save_cache — exactly what SIGKILL leaves behind.
    let blob_a = {
        let a = SweepEngine::new();
        assert_eq!(a.attach_journal(&jpath, 1).expect("attach"), 0);
        assert!(a.journal_attached());
        let out = a.run(&spec).expect("cold run");
        assert!(out.executed_sims >= 1);
        a.serialize_cache()
    };

    // Warm restart purely from the journal: every published record
    // replays, the rerun is pure cache, the serialized state is
    // byte-identical to what the dead engine held.
    let b = SweepEngine::new();
    let replayed = b.attach_journal(&jpath, 1).expect("recover");
    assert!(replayed >= 1, "the journal must hold the crashed run's records");
    assert_eq!(b.serialize_cache(), blob_a, "journal replay must be bit-identical");
    assert_eq!(b.run(&spec).expect("warm run").executed_sims, 0);

    // save_cache writes the snapshot atomically and compacts the
    // journal down to its bare header (12 bytes: magic + version).
    b.save_cache(&snap).expect("snapshot");
    assert_eq!(
        fs::metadata(&jpath).expect("journal exists").len(),
        12,
        "snapshot save must compact the journal",
    );

    // A third engine restarting from snapshot + compacted journal sees
    // the same world: zero journal records, zero sims to redo.
    let c = SweepEngine::new();
    assert!(c.load_cache(&snap).expect("snapshot loads") >= 1);
    assert_eq!(c.attach_journal(&jpath, 1).expect("attach"), 0);
    assert_eq!(c.serialize_cache(), blob_a);
    assert_eq!(c.run(&spec).expect("still warm").executed_sims, 0);
    let _ = fs::remove_file(&jpath);
    let _ = fs::remove_file(&snap);
}

#[test]
fn truncated_journal_tail_recovers_to_the_last_good_frame() {
    let _lock = TestLock::take();
    let jpath = tmp_path("torn-tail.swj");
    let spec = spec_of(&single_cell_request(1));
    {
        let a = SweepEngine::new();
        a.attach_journal(&jpath, 1).expect("attach");
        a.run(&spec).expect("run");
    }
    let full = fs::read(&jpath).expect("journal bytes");
    assert!(full.len() > 12, "journal must hold frames");

    // Chop one byte off the tail — a torn final frame. Recovery must
    // truncate at the frame boundary and keep every earlier record;
    // the engine re-simulates only what the torn frame lost.
    fs::write(&jpath, &full[..full.len() - 1]).expect("tear the tail");
    let b = SweepEngine::new();
    b.attach_journal(&jpath, 1).expect("recovery never errors on a torn tail");
    let out = b.run(&spec).expect("rerun");
    // The torn record is re-published into the recovered journal, so a
    // third start replays the complete run again.
    let c = SweepEngine::new();
    assert!(c.attach_journal(&jpath, 1).expect("attach") >= 1);
    assert_eq!(c.run(&spec).expect("warm").executed_sims, 0);
    // Whatever the tear cost, it never exceeds the full cold run.
    assert!(out.executed_sims <= 1, "{out:?}");
    let _ = fs::remove_file(&jpath);
}

// ---------------------------------------------------------------------------
// Serve-side fault injection
// ---------------------------------------------------------------------------

#[test]
fn node_item_fault_fails_exactly_the_planned_request() {
    let _lock = TestLock::take();
    faultline::install("node.item:fail@1").expect("valid plan");
    let shared =
        ServeShared::new(Arc::new(SweepEngine::new()), SpeedConfig::default(), unlimited());
    let input = format!(
        "{}\n{}\n",
        single_cell_request(1).to_line(),
        single_cell_request(2).to_line(),
    );
    let mut out: Vec<u8> = Vec::new();
    let stats = serve::serve_lines(&shared, BufReader::new(input.as_bytes()), &mut out);
    faultline::clear();
    let text = String::from_utf8(out).expect("utf-8");
    let lines: Vec<&str> = text.lines().collect();

    // First request: injected failure, structured error reply. Second
    // request (the trigger is spent): a clean block + summary.
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.errors, 1);
    assert!(
        lines[0].contains("\"type\":\"error\"") && lines[0].contains("fault injected"),
        "{}",
        lines[0],
    );
    let summary = lines.last().expect("summary");
    assert!(summary.contains("\"type\":\"summary\""), "{summary}");
    assert_eq!(field_u64(summary, "sims"), 1, "{summary}");
    // Latency telemetry rides every summary.
    let _ = field_u64(summary, "elapsed_ms");
    let _ = field_u64(summary, "gate_ms");
}

#[test]
fn periodic_flush_persists_the_cache_while_serving() {
    let _lock = TestLock::take();
    let cache = tmp_path("periodic.swc");
    let cache_str = cache.to_str().expect("utf-8 path").to_string();
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        unlimited(),
    ));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    let handle = {
        let shared = Arc::clone(&shared);
        let shutdown = Arc::clone(&shutdown);
        thread::spawn(move || {
            serve::run_tcp(&shared, listener, Some(&cache_str), 1, &shutdown).expect("run_tcp")
        })
    };

    // Simulate something worth saving, over a real connection.
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = stream;
    writeln!(writer, "{}", single_cell_request(1).to_line()).expect("send");
    writer.flush().expect("flush");
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).expect("reply") > 0, "server hung up");
        if line.contains("\"type\":\"summary\"") {
            break;
        }
    }

    // The accept loop flushes on its own cadence — no shutdown
    // needed. An early flush may capture the engine before the sweep
    // landed, so poll until a flushed file *loads* the simulation
    // (saves are atomic renames, so each read sees a complete file).
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut persisted = 0;
    while Instant::now() < deadline {
        if cache.exists() {
            persisted = SweepEngine::new().load_cache(&cache).expect("flushed file loads");
            if persisted >= 1 {
                break;
            }
        }
        thread::sleep(Duration::from_millis(50));
    }
    assert!(persisted >= 1, "periodic flush never persisted the simulation");

    drop(writer);
    drop(reader);
    shutdown.store(true, Ordering::SeqCst);
    let report = handle.join().expect("accept loop");
    assert!(report.flushes >= 1, "{report:?}");
    let _ = fs::remove_file(&cache);
}

// ---------------------------------------------------------------------------
// Fleet coordinator resume
// ---------------------------------------------------------------------------

#[test]
fn fleet_resume_from_a_complete_journal_is_a_pure_replay() {
    let _lock = TestLock::take();
    let jpath = tmp_path("fleet-complete.swj");
    let jstr = jpath.to_str().expect("utf-8 path").to_string();
    let (local_blocks, local_sims) = local_reference(&grid_request(7));

    let node = spawn_node();
    let mut opts = FleetOptions::new(
        vec![node.addr.clone()],
        SpeedConfig::default(),
        grid_request(7),
    );
    opts.cache_exchange = false;
    opts.journal = Some(jstr.clone());
    let out = run_fleet(&opts).expect("journaled fleet");
    assert_eq!(out.blocks, local_blocks);
    assert_eq!(out.sims, local_sims);
    // Per-node latency telemetry: percentile fields ride the records.
    let nline = node_line(&out.nodes[0]);
    assert!(field_u64(&nline, "p95_item_ms") >= field_u64(&nline, "p50_item_ms"), "{nline}");
    let sline = fleet_summary_line(7, &out);
    assert!(sline.contains("\"p50_item_ms\":") && sline.contains("\"p95_item_ms\":"), "{sline}");
    node.stop();

    // Resume against a node nothing listens on: a complete journal
    // replays the whole grid without one node transaction.
    let mut opts2 =
        FleetOptions::new(vec![dead_addr()], SpeedConfig::default(), grid_request(7));
    opts2.cache_exchange = false;
    opts2.journal = Some(jstr);
    opts2.resume = true;
    let resumed = run_fleet(&opts2).expect("pure replay needs no nodes");
    assert_eq!(resumed.blocks, local_blocks, "resumed blocks must be byte-identical");
    assert_eq!(resumed.nodes[0].items_done, 0, "{:?}", resumed.nodes);
    assert_eq!(resumed.nodes[0].failures, 0, "{:?}", resumed.nodes);
    assert_eq!(resumed.requeues, 0);
    let _ = fs::remove_file(&jpath);
}

#[test]
fn fleet_resume_after_a_torn_journal_redispatches_only_the_tail() {
    let _lock = TestLock::take();
    let jpath = tmp_path("fleet-torn.swj");
    let jstr = jpath.to_str().expect("utf-8 path").to_string();
    let (local_blocks, _) = local_reference(&grid_request(7));

    let node = spawn_node();
    let mut opts = FleetOptions::new(
        vec![node.addr.clone()],
        SpeedConfig::default(),
        grid_request(7),
    );
    opts.cache_exchange = false;
    opts.journal = Some(jstr.clone());
    let out = run_fleet(&opts).expect("journaled fleet");
    assert_eq!(out.blocks, local_blocks);

    // Tear the journal mid-frame (a coordinator killed mid-append):
    // recovery drops exactly the torn final record, so the resumed run
    // re-dispatches one item — to the same still-live node — and the
    // assembled output stays byte-identical.
    let full = fs::read(&jpath).expect("journal bytes");
    fs::write(&jpath, &full[..full.len() - 1]).expect("tear the tail");
    let mut opts2 = FleetOptions::new(
        vec![node.addr.clone()],
        SpeedConfig::default(),
        grid_request(7),
    );
    opts2.cache_exchange = false;
    opts2.journal = Some(jstr);
    opts2.resume = true;
    let resumed = run_fleet(&opts2).expect("partial resume");
    assert_eq!(resumed.blocks, local_blocks, "partial resume must not perturb a bit");
    let redone: u64 = resumed.nodes.iter().map(|n| n.items_done).sum();
    assert_eq!(redone, 1, "exactly the torn item re-dispatches: {:?}", resumed.nodes);

    node.stop();
    let _ = fs::remove_file(&jpath);
}

#[test]
fn fleet_resume_refuses_a_journal_from_a_different_plan() {
    let _lock = TestLock::take();
    let jpath = tmp_path("fleet-mismatch.swj");
    let jstr = jpath.to_str().expect("utf-8 path").to_string();

    let node = spawn_node();
    let mut opts = FleetOptions::new(
        vec![node.addr.clone()],
        SpeedConfig::default(),
        single_cell_request(3),
    );
    opts.cache_exchange = false;
    opts.journal = Some(jstr.clone());
    run_fleet(&opts).expect("seed journal");

    // Same journal path, different grid: the plan frame mismatches, so
    // resume recomputes from scratch instead of trusting stale state.
    let (local_blocks, _) = local_reference(&grid_request(7));
    let mut opts2 = FleetOptions::new(
        vec![node.addr.clone()],
        SpeedConfig::default(),
        grid_request(7),
    );
    opts2.cache_exchange = false;
    opts2.journal = Some(jstr);
    opts2.resume = true;
    let out = run_fleet(&opts2).expect("fresh start on mismatch");
    assert_eq!(out.blocks, local_blocks);
    let done: u64 = out.nodes.iter().map(|n| n.items_done).sum();
    assert_eq!(done, 3, "every item of the new plan must be dispatched: {:?}", out.nodes);

    node.stop();
    let _ = fs::remove_file(&jpath);
}
