//! Docs-drift guard: `docs/*.md` are the normative protocol and
//! format references, so their vocabulary is pinned to the constants
//! the implementation actually exports (`serve::REQUEST_FIELDS`,
//! `OP_NAMES`, `REPLY_TYPES`, `ERROR_CODES`). Adding a request field,
//! op, reply type or error code without documenting it fails here —
//! the CI docs leg runs exactly this test.

use speed::coordinator::serve;

const PROTOCOL_MD: &str = include_str!("../docs/PROTOCOL.md");
const ARCHITECTURE_MD: &str = include_str!("../docs/ARCHITECTURE.md");
const PERSIST_MD: &str = include_str!("../docs/PERSIST.md");

/// A protocol token counts as documented when it appears backticked
/// (`` `tok` ``) or as a table cell (`| `tok` |` renders via the same
/// backticks) anywhere in PROTOCOL.md.
fn documented(tok: &str) -> bool {
    PROTOCOL_MD.contains(&format!("`{tok}`"))
}

#[test]
fn every_request_field_is_documented() {
    for field in serve::REQUEST_FIELDS {
        assert!(
            documented(field),
            "PROTOCOL.md drifted: request field `{field}` is not documented"
        );
    }
}

#[test]
fn every_op_is_documented() {
    for op in serve::OP_NAMES {
        assert!(documented(op), "PROTOCOL.md drifted: op `{op}` is not documented");
    }
}

#[test]
fn every_reply_type_is_documented() {
    for ty in serve::REPLY_TYPES {
        assert!(
            documented(ty),
            "PROTOCOL.md drifted: reply type `{ty}` is not documented"
        );
    }
}

#[test]
fn every_error_code_is_documented() {
    for code in serve::ERROR_CODES {
        assert!(
            documented(code),
            "PROTOCOL.md drifted: error code `{code}` is not documented"
        );
    }
}

#[test]
fn protocol_md_documents_both_timeout_knobs() {
    // Satellite of the fix for the --timeout-secs / --idle-timeout-secs
    // confusion: the doc must name both knobs and both structured
    // error prefixes the client distinguishes them with.
    for needle in ["--timeout-secs", "--idle-timeout-secs", "read-timeout:", "idle-disconnect:"] {
        assert!(
            PROTOCOL_MD.contains(needle),
            "PROTOCOL.md drifted: timeout documentation lost `{needle}`"
        );
    }
}

#[test]
fn architecture_md_covers_the_layer_and_cache_maps() {
    for needle in [
        "isa", "dataflow", "coordinator", // the layer map
        "SimKey", "backend_fp", "cfg_fp", // memo key
        "delta", "program cache", "FNV-1a", // the cache hierarchy
        "speed fleet", "cache_export", "cache_import", // fleet topology
        "wavefront",
    ] {
        assert!(
            ARCHITECTURE_MD.contains(needle),
            "ARCHITECTURE.md drifted: missing `{needle}`"
        );
    }
}

#[test]
fn persist_md_matches_protocol_vocabulary() {
    // Byte-level constants are pinned inside persist.rs
    // (docs_match_wire_constants); here: the pieces shared with the
    // protocol surface.
    for needle in ["SPEEDSWC", "cache_export", "cache_import", "bad_blob", "blob_fingerprint"] {
        assert!(PERSIST_MD.contains(needle), "PERSIST.md drifted: missing `{needle}`");
    }
}

#[test]
fn docs_cross_link_each_other() {
    assert!(PROTOCOL_MD.contains("PERSIST.md"));
    assert!(ARCHITECTURE_MD.contains("PROTOCOL.md"));
    assert!(ARCHITECTURE_MD.contains("PERSIST.md"));
    assert!(PERSIST_MD.contains("PROTOCOL.md"));
}
