//! Multi-tenant serve stress contract. Two layers of coverage:
//!
//! * **Engine-level**, with instrumented test backends whose gates make
//!   the concurrency deterministic: identical cold runs from many
//!   threads coalesce onto exactly one simulation; a failing backend
//!   aborts its pending cell so concurrent waiters error instead of
//!   deadlocking; a high-priority request overtakes a long low-priority
//!   sweep on a single-permit engine.
//! * **Serve/TCP-level**, over the real protocol: concurrent sessions
//!   share one simulation across the whole fleet, the admission limit
//!   answers `"code":"overload"` and recovers, and the accept loop
//!   honours the connection cap, the idle read timeout and both
//!   shutdown paths (by request and by external flag), returning an
//!   accurate [`TcpReport`].
//!
//! Every assertion here is timing-*independent* (sums and orderings
//! that hold under any interleaving); sleeps and gates only make the
//! interesting interleavings overwhelmingly likely, they are never
//! load-bearing for correctness of the assertions.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::backend::{SimBackend, WorkerSlot};
use speed::coordinator::serve::{self, Op, Request, ServeLimits, ServeShared, Value};
use speed::coordinator::sweep::{SweepEngine, SweepOutcome, SweepSpec};
use speed::core::SimStats;
use speed::dataflow::{ConvLayer, Strategy};

const DEADLINE: Duration = Duration::from_secs(60);

/// Deterministic synthetic stats: a pure function of the cell key, so
/// any mix of coalescing / caching / re-simulation is bit-identical.
fn synth_stats(layer: &ConvLayer, p: Precision, strategy: Strategy) -> SimStats {
    let s = match strategy {
        Strategy::FeatureFirst => 1,
        Strategy::ChannelFirst => 2,
        Strategy::Mixed => 3,
    };
    SimStats {
        cycles: 1_000 + layer.cout as u64 * 17 + u64::from(p.bits()) * 7 + s,
        macs: 4096,
        useful_macs: 4096,
        ..Default::default()
    }
}

/// Blocks inside `simulate` until released, and counts entries — the
/// test holds the one real simulation open while every other thread
/// plans, which forces them all onto the pending cell.
#[derive(Debug)]
struct GatedBackend {
    entered: Arc<AtomicUsize>,
    release: Arc<AtomicBool>,
}

impl SimBackend for GatedBackend {
    fn name(&self) -> &'static str {
        "gated"
    }

    fn fingerprint(&self) -> u64 {
        0x6A7E_D001
    }

    fn simulate(
        &self,
        _slot: &mut WorkerSlot,
        _cfg: &SpeedConfig,
        layer: &ConvLayer,
        p: Precision,
        strategy: Strategy,
    ) -> speed::Result<SimStats> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let deadline = Instant::now() + DEADLINE;
        while !self.release.load(Ordering::SeqCst) {
            assert!(Instant::now() < deadline, "gated backend never released");
            thread::sleep(Duration::from_millis(2));
        }
        Ok(synth_stats(layer, p, strategy))
    }
}

/// Sleeps per cell (a "long" simulation) and counts entries.
#[derive(Debug)]
struct SlowBackend {
    delay: Duration,
    entered: Arc<AtomicUsize>,
}

impl SimBackend for SlowBackend {
    fn name(&self) -> &'static str {
        "slow"
    }

    fn fingerprint(&self) -> u64 {
        0x510B_ACC4
    }

    fn simulate(
        &self,
        _slot: &mut WorkerSlot,
        _cfg: &SpeedConfig,
        layer: &ConvLayer,
        p: Precision,
        strategy: Strategy,
    ) -> speed::Result<SimStats> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        thread::sleep(self.delay);
        Ok(synth_stats(layer, p, strategy))
    }
}

/// Always errors — exercises the pending-abort path under concurrency.
#[derive(Debug)]
struct FailingBackend;

impl SimBackend for FailingBackend {
    fn name(&self) -> &'static str {
        "failing"
    }

    fn fingerprint(&self) -> u64 {
        0xFA11_FA11
    }

    fn simulate(
        &self,
        _slot: &mut WorkerSlot,
        _cfg: &SpeedConfig,
        _layer: &ConvLayer,
        _p: Precision,
        _strategy: Strategy,
    ) -> speed::Result<SimStats> {
        Err(speed::Error::sim("injected backend failure"))
    }
}

fn one_layer_spec(cfg: &SpeedConfig, backend: Arc<dyn SimBackend>) -> SweepSpec {
    SweepSpec::new(cfg.clone())
        .network("t", vec![ConvLayer::new("c3", 8, 8, 8, 8, 3, 1, 1)])
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::FeatureFirst])
        .backends(vec![backend])
        .threads(1)
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + DEADLINE;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------------
// Engine-level concurrency
// ---------------------------------------------------------------------------

#[test]
fn concurrent_identical_cold_runs_coalesce_onto_one_simulation() {
    const N: usize = 8;
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new(AtomicBool::new(false));
    let backend: Arc<dyn SimBackend> = Arc::new(GatedBackend {
        entered: Arc::clone(&entered),
        release: Arc::clone(&release),
    });
    let cfg = SpeedConfig::default();
    let spec = Arc::new(one_layer_spec(&cfg, backend));
    let engine = Arc::new(SweepEngine::new());

    let barrier = Arc::new(Barrier::new(N));
    let mut runners = Vec::new();
    for _ in 0..N {
        let engine = Arc::clone(&engine);
        let spec = Arc::clone(&spec);
        let barrier = Arc::clone(&barrier);
        runners.push(thread::spawn(move || {
            barrier.wait();
            engine.run(&spec).expect("coalesced run")
        }));
    }

    // Exactly one thread claims the cell and blocks inside `simulate`;
    // the grace period lets the rest plan and park on the pending
    // entry before the owner is allowed to publish.
    wait_until("first simulate entry", || entered.load(Ordering::SeqCst) >= 1);
    thread::sleep(Duration::from_millis(100));
    release.store(true, Ordering::SeqCst);

    let outcomes: Vec<SweepOutcome> =
        runners.into_iter().map(|h| h.join().expect("runner thread")).collect();

    // The invariants below hold under ANY interleaving: one real
    // simulation total, and every other run got the value either by
    // coalescing onto the in-flight cell or from the cache afterwards.
    assert_eq!(entered.load(Ordering::SeqCst), 1, "backend must run exactly once");
    let sims: usize = outcomes.iter().map(|o| o.executed_sims).sum();
    let coalesced: usize = outcomes.iter().map(|o| o.coalesced_hits).sum();
    let cached: usize = outcomes.iter().map(|o| o.cache_hits).sum();
    assert_eq!(sims, 1, "exactly one simulation across {N} identical cold requests");
    assert_eq!(coalesced + cached, N - 1, "the other runs hit in-flight or cached");
    // The gate held the owner open for 100ms after the others started,
    // so at least one of them must have seen the pending cell.
    assert!(coalesced >= 1, "expected cross-request coalescing, got {coalesced}");

    // Bit-identical to a serial single-tenant run of the same spec.
    let serial_backend: Arc<dyn SimBackend> = Arc::new(GatedBackend {
        entered: Arc::new(AtomicUsize::new(0)),
        release: Arc::new(AtomicBool::new(true)),
    });
    let serial = SweepEngine::new()
        .run(&one_layer_spec(&cfg, serial_backend))
        .expect("serial run");
    for out in &outcomes {
        assert_eq!(out.results, serial.results, "concurrent result must be bit-identical");
        assert_eq!(out.jobs, serial.jobs);
    }
    assert_eq!(engine.pending_cells(), 0, "no pending cells may leak");
}

#[test]
fn concurrent_memoize_off_runs_share_the_delta_cache() {
    // Memoization off: every tenant simulates every cell for itself, so
    // the only thing the fleet can share is the engine-wide delta
    // cache. Deltas are keyed by stable fingerprints and all tenants
    // publish identical values, so the shared cache must converge to
    // exactly the single-tenant count — and a later tenant must replay
    // from it. All assertions hold under any interleaving.
    const N: usize = 6;
    let cfg = SpeedConfig::default();
    let spec = Arc::new(
        SweepSpec::new(cfg.clone())
            .network("t", vec![ConvLayer::new("steady", 16, 32, 40, 40, 3, 1, 1)])
            .precisions(vec![Precision::Int8])
            .strategies(vec![Strategy::Mixed])
            .memoize(false)
            .threads(1),
    );
    let engine = Arc::new(SweepEngine::new());
    let barrier = Arc::new(Barrier::new(N));
    let mut runners = Vec::new();
    for _ in 0..N {
        let engine = Arc::clone(&engine);
        let spec = Arc::clone(&spec);
        let barrier = Arc::clone(&barrier);
        runners.push(thread::spawn(move || {
            barrier.wait();
            engine.run(&spec).expect("memoize-off run")
        }));
    }
    let outcomes: Vec<SweepOutcome> =
        runners.into_iter().map(|h| h.join().expect("runner thread")).collect();

    let solo = SweepEngine::new();
    let solo_out = solo.run(&spec).expect("solo run");
    assert!(solo.cached_deltas() > 0, "the layer must publish converged deltas");
    for out in &outcomes {
        assert_eq!(out.cache_hits, 0, "memoize-off tenants never hit the memo table");
        assert!(out.executed_sims > 0);
        assert_eq!(out.results, solo_out.results, "tenant result must be bit-identical");
    }
    assert_eq!(
        engine.cached_deltas(),
        solo.cached_deltas(),
        "{N} concurrent publishers must agree on every delta key"
    );
    // A late tenant joining the warm fleet replays published deltas.
    let warm = engine.run(&spec).expect("warm run");
    assert!(warm.delta_cache_hits > 0, "a later tenant must replay the fleet's deltas");
    assert_eq!(warm.results, solo_out.results);
}

#[test]
fn failing_backend_aborts_pending_so_waiters_error_instead_of_deadlocking() {
    let cfg = SpeedConfig::default();
    let spec = Arc::new(one_layer_spec(&cfg, Arc::new(FailingBackend)));
    let engine = Arc::new(SweepEngine::new());

    let barrier = Arc::new(Barrier::new(2));
    let mut runners = Vec::new();
    for _ in 0..2 {
        let engine = Arc::clone(&engine);
        let spec = Arc::clone(&spec);
        let barrier = Arc::clone(&barrier);
        runners.push(thread::spawn(move || {
            barrier.wait();
            engine.run(&spec)
        }));
    }
    for h in runners {
        let res = h.join().expect("runner thread must not deadlock or panic");
        assert!(res.is_err(), "a failing backend must surface an error");
    }
    // The aborted pending cell is fully cleaned up: nothing cached,
    // nothing in flight, and the engine still works afterwards.
    assert_eq!(engine.pending_cells(), 0);
    assert_eq!(engine.cached_sims(), 0);
    assert!(engine.run(&spec).is_err(), "engine stays usable (and still errors)");
}

#[test]
fn high_priority_request_overtakes_a_long_low_priority_sweep() {
    let entered = Arc::new(AtomicUsize::new(0));
    let backend: Arc<dyn SimBackend> = Arc::new(SlowBackend {
        delay: Duration::from_millis(30),
        entered: Arc::clone(&entered),
    });
    let cfg = SpeedConfig::default();

    // One simulation permit engine-wide: every cell of every request
    // funnels through the priority gate one at a time.
    let mut engine = SweepEngine::new();
    engine.set_worker_budget(Some(1));
    let engine = Arc::new(engine);

    // Ten distinct shapes = ten serialized 30ms cells for the big sweep.
    let big_layers: Vec<ConvLayer> = (0..10)
        .map(|i| ConvLayer::new(&format!("big{i}"), 8, 8 + i, 8, 8, 3, 1, 1))
        .collect();
    let big = SweepSpec::new(cfg.clone())
        .network("big", big_layers)
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::FeatureFirst])
        .backends(vec![Arc::clone(&backend)])
        .threads(1)
        .priority(0);
    let small = SweepSpec::new(cfg.clone())
        .network("small", vec![ConvLayer::new("sm", 8, 64, 8, 8, 1, 1, 0)])
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::FeatureFirst])
        .backends(vec![Arc::clone(&backend)])
        .threads(1)
        .priority(9);

    let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
    let big_thread = {
        let engine = Arc::clone(&engine);
        let order = Arc::clone(&order);
        thread::spawn(move || {
            let out = engine.run(&big).expect("big sweep");
            order.lock().unwrap().push("big");
            out
        })
    };
    // Only submit the small request once the big sweep is visibly
    // mid-flight, so "small finishes first" can only come from the
    // priority gate, not from lucky scheduling.
    wait_until("big sweep underway", || entered.load(Ordering::SeqCst) >= 1);
    let small_thread = {
        let engine = Arc::clone(&engine);
        let order = Arc::clone(&order);
        thread::spawn(move || {
            let out = engine.run(&small).expect("small sweep");
            order.lock().unwrap().push("small");
            out
        })
    };

    let small_out = small_thread.join().expect("small thread");
    let big_out = big_thread.join().expect("big thread");
    let order = order.lock().unwrap();
    assert_eq!(
        *order,
        ["small", "big"],
        "priority 9 request must complete before the 10-cell priority-0 sweep"
    );
    assert_eq!(small_out.executed_sims, 1);
    assert_eq!(big_out.executed_sims, 10);
    assert_eq!(entered.load(Ordering::SeqCst), 11);
}

// ---------------------------------------------------------------------------
// Serve-level (protocol) concurrency
// ---------------------------------------------------------------------------

/// A tiny cold sweep request: one small SqueezeNet layer, int8, FF.
fn tiny_request(id: u64) -> Request {
    Request {
        id,
        network: "SqueezeNet".into(),
        layers: Some(vec![1]),
        precisions: vec![Precision::Int8],
        strategies: vec![Strategy::FeatureFirst],
        threads: Some(1),
        ..Default::default()
    }
}

fn unlimited() -> ServeLimits {
    ServeLimits { max_connections: 0, max_concurrent_sweeps: 0, idle_timeout_secs: 0 }
}

fn serve_session(shared: &ServeShared, input: &str) -> (Vec<String>, serve::ServeStats) {
    let mut out: Vec<u8> = Vec::new();
    let stats = serve::serve_lines(shared, BufReader::new(input.as_bytes()), &mut out);
    let text = String::from_utf8(out).expect("utf-8 reply stream");
    (text.lines().map(String::from).collect(), stats)
}

fn field_u64(line: &str, key: &str) -> u64 {
    for (k, v) in serve::parse_record(line).expect("reply line parses") {
        if k == key {
            match v {
                Value::Int(n) => return n,
                other => panic!("field `{key}` is {other:?}, wanted int, in {line}"),
            }
        }
    }
    panic!("missing field `{key}` in {line}");
}

fn field_str(line: &str, key: &str) -> String {
    for (k, v) in serve::parse_record(line).expect("reply line parses") {
        if k == key {
            match v {
                Value::Str(s) => return s,
                other => panic!("field `{key}` is {other:?}, wanted string, in {line}"),
            }
        }
    }
    panic!("missing field `{key}` in {line}");
}

#[test]
fn concurrent_serve_sessions_share_one_simulation_across_the_fleet() {
    const N: usize = 16;
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        unlimited(),
    ));
    let input = format!("{}\n", tiny_request(1).to_line());

    let barrier = Arc::new(Barrier::new(N));
    let mut sessions = Vec::new();
    for _ in 0..N {
        let shared = Arc::clone(&shared);
        let input = input.clone();
        let barrier = Arc::clone(&barrier);
        sessions.push(thread::spawn(move || {
            barrier.wait();
            serve_session(&shared, &input)
        }));
    }
    let replies: Vec<(Vec<String>, serve::ServeStats)> =
        sessions.into_iter().map(|h| h.join().expect("session thread")).collect();

    let mut sims = 0;
    let mut coalesced = 0;
    let mut cached = 0;
    for (lines, stats) in &replies {
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.errors, 0);
        assert_eq!(lines.len(), 2, "block + summary, got {lines:?}");
        assert!(lines[0].contains("\"type\":\"block\""), "{}", lines[0]);
        assert!(lines[1].contains("\"type\":\"summary\""), "{}", lines[1]);
        sims += field_u64(&lines[1], "sims");
        coalesced += field_u64(&lines[1], "coalesced");
        cached += field_u64(&lines[1], "cache_hits");
    }
    assert_eq!(sims, 1, "one simulation total across {N} concurrent sessions");
    assert_eq!(coalesced + cached, (N as u64) - 1);

    // Every session saw the identical block line, and it matches a
    // fresh single-tenant server answering the same request.
    let (serial_lines, _) = serve_session(
        &ServeShared::new(Arc::new(SweepEngine::new()), SpeedConfig::default(), unlimited()),
        &input,
    );
    for (lines, _) in &replies {
        assert_eq!(lines[0], serial_lines[0], "blocks must be bit-identical to serial");
    }
}

#[test]
fn sweep_admission_limit_answers_overload_and_recovers() {
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        ServeLimits { max_concurrent_sweeps: 1, ..unlimited() },
    ));

    // Session A: a multi-cell grid (2 layers × 2 precisions, mixed
    // strategy) — long enough that B's admission check below runs
    // strictly inside it (we poll for admission before sending B).
    let big = Request {
        id: 1,
        network: "SqueezeNet".into(),
        layers: Some(vec![1, 2]),
        precisions: vec![Precision::Int8, Precision::Int4],
        threads: Some(2),
        ..Default::default()
    };
    let a = {
        let shared = Arc::clone(&shared);
        let input = format!("{}\n", big.to_line());
        thread::spawn(move || serve_session(&shared, &input))
    };
    wait_until("big sweep admitted", || shared.active_sweeps() == 1);

    // Session B is refused immediately with a structured overload
    // error — it never queues and never touches the engine.
    let (b_lines, b_stats) = serve_session(&shared, &format!("{}\n", tiny_request(2).to_line()));
    assert_eq!(b_lines.len(), 1, "one refusal line, got {b_lines:?}");
    assert_eq!(field_str(&b_lines[0], "type"), "error");
    assert_eq!(field_str(&b_lines[0], "code"), "overload");
    assert_eq!(field_u64(&b_lines[0], "id"), 2);
    assert_eq!(b_stats.errors, 1);
    assert_eq!(b_stats.overloads, 1);

    let (a_lines, a_stats) = a.join().expect("session A");
    assert_eq!(a_stats.overloads, 0);
    assert!(a_lines.last().expect("reply").contains("\"type\":\"summary\""));

    // The permit was released: the same request now succeeds, and the
    // shared engine makes it pure cache.
    assert_eq!(shared.active_sweeps(), 0);
    let (c_lines, c_stats) = serve_session(&shared, &format!("{}\n", big.to_line()));
    assert_eq!(c_stats.overloads, 0);
    let summary = c_lines.last().expect("summary");
    assert_eq!(field_u64(summary, "sims"), 0, "warm repeat must be pure cache: {summary}");
}

#[test]
fn expired_deadline_is_answered_with_a_structured_deadline_error() {
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        unlimited(),
    ));
    // A zero deadline has always already passed by the time the worker
    // acquires a scheduler permit, so the item is dropped rather than
    // simulated and the session answers with `"code":"deadline"`.
    let req = Request { deadline_ms: Some(0), ..tiny_request(7) };
    let (lines, stats) = serve_session(&shared, &format!("{}\n", req.to_line()));
    assert_eq!(lines.len(), 1, "one structured error line, got {lines:?}");
    assert_eq!(field_str(&lines[0], "type"), "error");
    assert_eq!(field_str(&lines[0], "code"), "deadline");
    assert_eq!(field_u64(&lines[0], "id"), 7);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.overloads, 0, "a deadline drop is not an admission refusal");
    // Nothing was simulated or published for the dropped work.
    assert_eq!(shared.engine.cached_sims(), 0);
    // The same request without a deadline then succeeds normally.
    let (ok_lines, ok_stats) = serve_session(&shared, &format!("{}\n", tiny_request(8).to_line()));
    assert_eq!(ok_stats.errors, 0);
    assert!(ok_lines.last().expect("reply").contains("\"type\":\"summary\""));
}

// ---------------------------------------------------------------------------
// TCP accept loop
// ---------------------------------------------------------------------------

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).expect("send line");
    stream.write_all(b"\n").expect("send newline");
    stream.flush().expect("flush");
}

fn read_reply(stream: &TcpStream) -> String {
    let mut reader = BufReader::new(stream.try_clone().expect("clone for read"));
    let mut line = String::new();
    reader.read_line(&mut line).expect("read reply");
    line.trim_end().to_string()
}

/// One ping round-trip; `None` on any refusal or socket error (the
/// caller retries). The generous read timeout only bounds a wedged
/// server — a live one answers in microseconds.
fn try_ping(addr: std::net::SocketAddr, id: u64) -> Option<String> {
    let mut c = TcpStream::connect(addr).ok()?;
    c.set_read_timeout(Some(Duration::from_secs(10))).ok()?;
    let line = Request { id, op: Op::Ping, ..Default::default() }.to_line();
    c.write_all(line.as_bytes()).ok()?;
    c.write_all(b"\n").ok()?;
    c.flush().ok()?;
    let mut reply = String::new();
    BufReader::new(c).read_line(&mut reply).ok()?;
    let reply = reply.trim_end().to_string();
    reply.contains("\"type\":\"pong\"").then_some(reply)
}

fn spawn_tcp(
    shared: &Arc<ServeShared>,
    listener: TcpListener,
    shutdown: &Arc<AtomicBool>,
) -> thread::JoinHandle<serve::TcpReport> {
    let shared = Arc::clone(shared);
    let shutdown = Arc::clone(shutdown);
    thread::spawn(move || serve::run_tcp(&shared, listener, None, 0, &shutdown).expect("run_tcp"))
}

#[test]
fn tcp_connection_cap_idle_timeout_and_flag_shutdown() {
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        ServeLimits { max_connections: 1, max_concurrent_sweeps: 0, idle_timeout_secs: 1 },
    ));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = spawn_tcp(&shared, listener, &shutdown);

    // Connection 1 takes the only slot and goes idle.
    let idle = TcpStream::connect(addr).expect("idle client connects");

    // Connection 2 is over the cap: refused at accept with a
    // structured overload error, then closed.
    let over = TcpStream::connect(addr).expect("overflow client connects");
    let refusal = read_reply(&over);
    assert_eq!(field_str(&refusal, "type"), "error", "{refusal}");
    assert_eq!(field_str(&refusal, "code"), "overload", "{refusal}");

    // The idle session dies on the 1s read timeout, freeing the slot;
    // a fresh client then gets served. Attempts that race the reaper
    // are refused (overload line or a reset, depending on how far the
    // client's write got before the server closed) — just retry.
    let deadline = Instant::now() + DEADLINE;
    let pong = loop {
        assert!(Instant::now() < deadline, "slot never freed after idle timeout");
        if let Some(reply) = try_ping(addr, 3) {
            break reply;
        }
        thread::sleep(Duration::from_millis(100));
    };
    assert_eq!(field_u64(&pong, "id"), 3);
    drop(idle);

    // External-flag shutdown: the nonblocking accept loop notices the
    // flag on its next poll — no wake-up connection required.
    shutdown.store(true, Ordering::SeqCst);
    let report = server.join().expect("server thread");
    assert!(report.connections >= 2, "idle + served client at least: {report:?}");
    assert!(report.rejected >= 1, "the over-cap client was refused: {report:?}");
    assert_eq!(report.panicked_sessions, 0, "{report:?}");
}

#[test]
fn tcp_shutdown_request_ends_the_accept_loop_deterministically() {
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        ServeLimits::default(),
    ));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = spawn_tcp(&shared, listener, &shutdown);

    let mut client = TcpStream::connect(addr).expect("client connects");
    send_line(&mut client, &Request { id: 9, op: Op::Shutdown, ..Default::default() }.to_line());
    let bye = read_reply(&client);
    assert!(bye.contains("\"type\":\"bye\""), "{bye}");

    // The session flips the flag; the accept loop exits on its own and
    // every session thread is joined into the report.
    let report = server.join().expect("server thread");
    assert!(shutdown.load(Ordering::SeqCst));
    assert_eq!(report.connections, 1, "{report:?}");
    assert_eq!(report.panicked_sessions, 0, "{report:?}");
}
