//! Cross-process cache persistence contract: save → reload → rerun
//! performs **zero** new simulations and reproduces the `SweepOutcome`
//! bit-identically; malformed cache files are rejected gracefully (an
//! error, never a panic) and leave the engine on a cold cache.

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::backend::AraAnalytic;
use speed::coordinator::sweep::{SweepEngine, SweepSpec};
use speed::dataflow::{ConvLayer, Strategy};

fn small_spec(cfg: &SpeedConfig) -> SweepSpec {
    SweepSpec::new(cfg.clone())
        .network(
            "t",
            vec![
                ConvLayer::new("c3", 8, 8, 8, 8, 3, 1, 1),
                ConvLayer::new("pw", 8, 12, 6, 6, 1, 1, 0),
                ConvLayer::new("c3_dup", 8, 8, 8, 8, 3, 1, 1),
            ],
        )
        .precisions(vec![Precision::Int8, Precision::Int4])
        .strategies(vec![Strategy::Mixed])
        .backend(AraAnalytic::default())
        .threads(2)
}

/// Speed-backend spec with a layer steady enough to publish converged
/// deltas (the analytic backends never do — delta records only exist
/// for the cycle engine's steady-state regions).
fn delta_spec(cfg: &SpeedConfig) -> SweepSpec {
    SweepSpec::new(cfg.clone())
        .network(
            "t",
            vec![
                ConvLayer::new("c3", 8, 8, 8, 8, 3, 1, 1),
                ConvLayer::new("steady", 16, 32, 40, 40, 3, 1, 1),
            ],
        )
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::Mixed])
        .threads(2)
}

/// Unique scratch path per test (the test binary may run tests in
/// parallel threads).
fn scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("speed_cache_{}_{}.swc", tag, std::process::id()))
}

#[test]
fn save_reload_rerun_is_pure_cache_and_bit_identical() {
    let cfg = SpeedConfig::default();
    let spec = small_spec(&cfg);
    let warm_engine = SweepEngine::new();
    let cold = warm_engine.run(&spec).unwrap();
    assert!(cold.executed_sims > 0);
    assert_eq!(cold.cache_hits, 0);

    let path = scratch("roundtrip");
    warm_engine.save_cache(&path).unwrap();

    // A brand-new engine (≈ a restarted process) loads the file…
    let fresh = SweepEngine::new();
    assert_eq!(fresh.cached_sims(), 0);
    let loaded = fresh.load_cache(&path).unwrap();
    assert_eq!(loaded, warm_engine.cached_sims());
    assert_eq!(fresh.cached_sims(), warm_engine.cached_sims());

    // …and reruns the grid without a single new simulation.
    let replay = fresh.run(&spec).unwrap();
    assert_eq!(replay.executed_sims, 0, "every cell must come from the loaded cache");
    assert_eq!(replay.cache_hits, cold.executed_sims);
    assert_eq!(replay.results, cold.results, "replay must be bit-identical");
    assert_eq!(replay.jobs, cold.jobs);

    std::fs::remove_file(&path).ok();
}

#[test]
fn serialized_bytes_round_trip_and_are_deterministic() {
    let cfg = SpeedConfig::default();
    let engine = SweepEngine::new();
    engine.run(&small_spec(&cfg)).unwrap();
    let a = engine.serialize_cache();
    let b = engine.serialize_cache();
    assert_eq!(a, b, "serialization must be deterministic");
    let other = SweepEngine::new();
    assert_eq!(other.load_cache_bytes(&a).unwrap(), engine.cached_sims());
    assert_eq!(other.serialize_cache(), a, "decode→encode must be the identity");
}

#[test]
fn corrupted_and_mismatched_caches_are_rejected_without_panic() {
    let cfg = SpeedConfig::default();
    let spec = small_spec(&cfg);
    let engine = SweepEngine::new();
    engine.run(&spec).unwrap();
    let good = engine.serialize_cache();

    let victim = SweepEngine::new();
    // Garbage, empty, truncated, bit-flipped and version-bumped inputs
    // must all error out and leave the cache untouched (cold).
    assert!(victim.load_cache_bytes(b"definitely not a cache file").is_err());
    assert!(victim.load_cache_bytes(&[]).is_err());
    assert!(victim.load_cache_bytes(&good[..good.len() / 2]).is_err());
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0xA5;
    assert!(victim.load_cache_bytes(&flipped).is_err());
    let mut versioned = good.clone();
    versioned[8] = 0x7F; // version field, straight after the 8-byte magic
    assert!(victim.load_cache_bytes(&versioned).is_err());
    assert_eq!(victim.cached_sims(), 0, "failed loads must not pollute the cache");

    // A missing file is an error too (callers fall back to cold).
    assert!(victim.load_cache(scratch("missing")).is_err());

    // The cold engine still runs the grid fine afterwards.
    let out = victim.run(&spec).unwrap();
    assert!(out.executed_sims > 0);
}

#[test]
fn persisted_deltas_replay_after_reload() {
    let cfg = SpeedConfig::default();
    let spec = delta_spec(&cfg);
    let donor = SweepEngine::new();
    let cold = donor.run(&spec).unwrap();
    assert!(donor.cached_deltas() > 0, "the grid must publish converged deltas");
    let bytes = donor.serialize_cache();

    // A brand-new engine (≈ a restarted process) loads the deltas along
    // with the memo entries…
    let fresh = SweepEngine::new();
    fresh.load_cache_bytes(&bytes).unwrap();
    assert_eq!(fresh.cached_deltas(), donor.cached_deltas());
    // …and a re-simulation (memoization off, so the memo table can't
    // answer) replays them, bit-identically to the donor's cold run.
    let warm = fresh.run(&spec.clone().memoize(false)).unwrap();
    assert!(warm.executed_sims > 0, "memoize-off must actually re-simulate");
    assert!(warm.delta_cache_hits > 0, "persisted deltas must replay");
    assert_eq!(warm.results, cold.results, "delta replay must be bit-identical");
}

#[test]
fn corrupted_delta_section_is_rejected_and_falls_back_cold() {
    let cfg = SpeedConfig::default();
    let spec = delta_spec(&cfg);
    let donor = SweepEngine::new();
    let cold = donor.run(&spec).unwrap();
    assert!(donor.cached_deltas() > 0, "need a delta section to corrupt");
    let good = donor.serialize_cache();

    // Flip a byte inside the trailing delta records (the footer is the
    // last 8 bytes; aim well before it): the checksum rejects the file
    // and the engine stays cold on both tables.
    let mut mangled = good.clone();
    let at = mangled.len() - 16;
    mangled[at] ^= 0x5A;
    let victim = SweepEngine::new();
    assert!(victim.load_cache_bytes(&mangled).is_err());
    assert_eq!(victim.cached_sims(), 0, "rejected file must not seed the memo table");
    assert_eq!(victim.cached_deltas(), 0, "rejected file must not seed the delta cache");

    // The cold engine still simulates the grid fine, bit-identically.
    let out = victim.run(&spec).unwrap();
    assert!(out.executed_sims > 0);
    assert_eq!(out.results, cold.results);
}

#[test]
fn bounded_engine_loads_huge_cache_file_without_exceeding_cap() {
    // Regression: `load_cache` merge semantics used to be unbounded, so
    // a huge on-disk cache could blow a resident server's memory. The
    // LRU bound now applies to the load-time merge too.
    let cfg = SpeedConfig::default();
    let spec = small_spec(&cfg);
    let donor = SweepEngine::new();
    donor.run(&spec).unwrap();
    assert!(donor.cached_sims() > 2, "need more entries than the bound");
    let path = scratch("bounded_load");
    donor.save_cache(&path).unwrap();

    let bounded = SweepEngine::new();
    bounded.set_max_cache_entries(Some(2));
    let loaded = bounded.load_cache(&path).unwrap();
    assert_eq!(loaded, donor.cached_sims(), "reports the file's entry count");
    assert_eq!(bounded.cached_sims(), 2, "merge respects the cap");
    assert_eq!(bounded.cache_evictions() as usize, loaded - 2);
    // The bounded engine still replays the grid bit-identically (the
    // evicted cells re-simulate, the retained ones hit).
    let replay = bounded.run(&spec).unwrap();
    assert!(replay.executed_sims > 0, "evicted cells must re-simulate");
    assert!(replay.cache_hits > 0, "retained cells must hit");
    let full = donor.run(&spec).unwrap();
    assert_eq!(replay.results, full.results);
    std::fs::remove_file(&path).ok();
}

#[test]
fn cache_files_merge_and_ignore_foreign_configurations() {
    // Entries are keyed by (backend, config) fingerprints: a cache
    // saved under one machine configuration never hits under another.
    let base = SpeedConfig::default();
    let spec_base = small_spec(&base);
    let engine = SweepEngine::new();
    let cold = engine.run(&spec_base).unwrap();
    let bytes = engine.serialize_cache();

    let other_cfg = SpeedConfig { tile_r: 8, tile_c: 8, ..Default::default() };
    let other = SweepEngine::new();
    other.load_cache_bytes(&bytes).unwrap();
    let foreign_spec = SweepSpec::new(other_cfg)
        .network("t", vec![ConvLayer::new("c3", 8, 8, 8, 8, 3, 1, 1)])
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::FeatureFirst])
        .threads(1);
    let foreign = other.run(&foreign_spec).unwrap();
    assert_eq!(foreign.cache_hits, 0, "foreign config must not hit the loaded cache");
    assert!(foreign.executed_sims > 0);
    // …while the original grid still replays purely from cache, plus
    // the foreign entries now coexist in the merged table.
    let replay = other.run(&spec_base).unwrap();
    assert_eq!(replay.executed_sims, 0);
    assert_eq!(replay.results, cold.results);
}
