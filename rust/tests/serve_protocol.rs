//! Serve-protocol contract: request parse ∘ serialize is the identity
//! over every field (property-tested), malformed lines are rejected
//! with errors (never panics), a full in-process serve session streams
//! blocks + summary per request — with a warm repeat of an identical
//! request performing **zero** new simulations — and the engine's LRU
//! cache bound evicts deterministically, with evicted cells
//! re-simulating on the next request.

use std::io::BufReader;
use std::sync::Arc;

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::serve::{
    self, parse_record, CfgOverrides, Op, Request, Value,
};
use speed::coordinator::sweep::{SweepEngine, SweepSpec};
use speed::dataflow::{ConvLayer, Strategy};
use speed::testutil::Prng;

/// Non-empty subset of `0..n`, in index order, no duplicates.
fn pick_subset(rng: &mut Prng, n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    for i in 0..n {
        if rng.below(2) == 1 {
            out.push(i);
        }
    }
    if out.is_empty() {
        out.push(rng.below(n as u64) as usize);
    }
    out
}

fn random_request(rng: &mut Prng) -> Request {
    let nets = ["VGG16", "ResNet18", "GoogLeNet", "SqueezeNet"];
    let backends = ["speed", "ara", "golden", "roofline"];
    let precisions = [Precision::Int4, Precision::Int8, Precision::Int16];
    let strategies = [Strategy::FeatureFirst, Strategy::ChannelFirst, Strategy::Mixed];
    let mut req = Request {
        id: rng.next_u64() >> 12, // keep within exact-integer range
        op: match rng.below(4) {
            0 => Op::Ping,
            1 => Op::Shutdown,
            _ => Op::Sweep,
        },
        network: nets[rng.below(nets.len() as u64) as usize].to_string(),
        ..Default::default()
    };
    if rng.below(2) == 1 {
        req.layers = Some(pick_subset(rng, 12));
    }
    if rng.below(2) == 1 {
        req.backends = pick_subset(rng, backends.len())
            .into_iter()
            .map(|i| backends[i].to_string())
            .collect();
    }
    if rng.below(2) == 1 {
        req.precisions = pick_subset(rng, 3).into_iter().map(|i| precisions[i]).collect();
    }
    if rng.below(2) == 1 {
        req.strategies = pick_subset(rng, 3).into_iter().map(|i| strategies[i]).collect();
    }
    if rng.below(2) == 1 {
        req.threads = Some(rng.below(16) as usize);
    }
    req.memoize = rng.below(4) != 0;
    req.shard = rng.below(4) != 0;
    if rng.below(2) == 1 {
        req.shard_threshold = Some(rng.next_u64() >> 12);
    }
    req.fast_forward = rng.below(4) != 0;
    if rng.below(2) == 1 {
        req.priority = rng.below(256) as u8;
    }
    req.overrides = CfgOverrides {
        lanes: (rng.below(2) == 1).then(|| 1 << rng.range_usize(2, 4)),
        vlen: (rng.below(2) == 1).then(|| 512 << rng.range_usize(0, 2)),
        tile_r: (rng.below(2) == 1).then(|| rng.range_usize(2, 8)),
        tile_c: (rng.below(2) == 1).then(|| rng.range_usize(2, 8)),
        dram_bw: (rng.below(2) == 1).then(|| rng.range_usize(8, 64) as f64 / 2.0),
        freq: (rng.below(2) == 1).then(|| rng.range_usize(100, 1500) as f64),
    };
    req
}

#[test]
fn request_round_trips_over_all_fields() {
    let mut rng = Prng::new(0x5E12_17E5);
    for i in 0..300 {
        let req = random_request(&mut rng);
        let line = req.to_line();
        let back = Request::parse(&line)
            .unwrap_or_else(|e| panic!("iteration {i}: {e}\nline: {line}"));
        assert_eq!(back, req, "iteration {i}: round-trip diverged\nline: {line}");
        // Serialization is deterministic and idempotent.
        assert_eq!(back.to_line(), line, "iteration {i}");
    }
}

#[test]
fn malformed_requests_are_rejected_not_panics() {
    for bad in [
        "",
        "{",
        "]",
        "{\"id\":1,\"network\":\"VGG",          // truncated string
        "{\"id\":1,\"network\":\"VGG16\"",      // truncated object
        "{\"id\":1,\"network\":\"VGG16\"} junk", // trailing garbage
        "{\"id\":1,\"id\":2}",                  // duplicate field
        "{\"id\":1,\"flavor\":\"blue\"}",       // unknown field
        "{\"id\":-1}",                          // negative id
        "{\"id\":1,\"layers\":[]}",             // empty subset
        "{\"id\":1,\"backends\":[\"riscv\"]}",  // unknown backend
        "{\"id\":1,\"precisions\":[7]}",        // unknown precision
        "{\"id\":1,\"strategies\":[\"zz\"]}",   // unknown strategy
        "{\"id\":1,\"network\":42}",            // wrong type
        "{\"id\":[1]}",                         // wrong shape
        "{\"id\":1,\"shard\":1}",               // shard wants a bool
        "{\"id\":1,\"shard_threshold\":\"x\"}", // threshold wants an int
        "{\"id\":1,\"fast_forward\":1}",        // fast_forward wants a bool
        "{\"id\":1,\"priority\":300}",          // priority out of u8 range
        "{\"id\":1,\"priority\":\"high\"}",     // priority wants an int
    ] {
        assert!(Request::parse(bad).is_err(), "must reject {bad:?}");
    }
}

/// Drive one in-process serve session and return its reply lines.
fn serve_session(engine: &Arc<SweepEngine>, input: &str) -> (Vec<String>, serve::ServeStats) {
    let shared = serve::ServeShared::new(
        Arc::clone(engine),
        SpeedConfig::default(),
        serve::ServeLimits::default(),
    );
    let mut out: Vec<u8> = Vec::new();
    let stats = serve::serve_lines(&shared, BufReader::new(input.as_bytes()), &mut out);
    let text = String::from_utf8(out).expect("utf-8 reply stream");
    (text.lines().map(String::from).collect(), stats)
}

fn record_type(line: &str) -> String {
    let fields = parse_record(line).unwrap_or_else(|e| panic!("unparseable reply {line:?}: {e}"));
    match fields.iter().find(|(k, _)| k == "type") {
        Some((_, Value::Str(s))) => s.clone(),
        other => panic!("reply without string `type`: {line:?} ({other:?})"),
    }
}

fn summary_field(line: &str, name: &str) -> u64 {
    let fields = parse_record(line).unwrap();
    match fields.iter().find(|(k, _)| k == name) {
        Some((_, Value::Int(v))) => *v,
        other => panic!("summary field `{name}` missing/non-integer: {line:?} ({other:?})"),
    }
}

#[test]
fn serve_session_streams_blocks_and_summaries_with_warm_repeat_zero_sims() {
    // Two identical sweep requests (one tiny SqueezeNet layer), one
    // malformed line in between, then shutdown. The warm repeat must
    // execute zero simulations — the acceptance criterion.
    let sweep = Request {
        id: 1,
        network: "SqueezeNet".into(),
        layers: Some(vec![1]),
        precisions: vec![Precision::Int8],
        strategies: vec![Strategy::FeatureFirst],
        threads: Some(1),
        ..Default::default()
    };
    let warm = Request { id: 2, ..sweep.clone() };
    let input = format!(
        "{}\nthis is not a record\n{}\n{}\n",
        sweep.to_line(),
        warm.to_line(),
        Request { id: 9, op: Op::Shutdown, ..Default::default() }.to_line()
    );
    let engine = Arc::new(SweepEngine::new());
    let (lines, stats) = serve_session(&engine, &input);

    assert_eq!(stats.requests, 4);
    assert_eq!(stats.errors, 1);
    assert!(stats.shutdown);

    let types: Vec<String> = lines.iter().map(|l| record_type(l)).collect();
    assert_eq!(
        types,
        vec!["block", "summary", "error", "block", "summary", "bye"],
        "reply stream shape: {lines:#?}"
    );
    // Cold request: exactly one simulation (1 layer × int8 × ff).
    assert_eq!(summary_field(&lines[1], "id"), 1);
    assert_eq!(summary_field(&lines[1], "sims"), 1);
    assert_eq!(summary_field(&lines[1], "jobs"), 1);
    assert_eq!(summary_field(&lines[1], "cache_entries"), 1);
    // Tiny layer: below every shard threshold, so no fan-out — but the
    // accounting fields are always present in the summary.
    assert_eq!(summary_field(&lines[1], "sharded_jobs"), 0);
    assert_eq!(summary_field(&lines[1], "shards"), 0);
    let _ = summary_field(&lines[1], "slowest_job_ms");
    // Concurrency telemetry is always present; a serial session never
    // coalesces on another request's in-flight cell.
    assert_eq!(summary_field(&lines[1], "coalesced"), 0);
    let _ = summary_field(&lines[1], "queue_ms");
    // Warm repeat: zero new simulations, served from the shared memo.
    assert_eq!(summary_field(&lines[4], "id"), 2);
    assert_eq!(summary_field(&lines[4], "sims"), 0);
    assert_eq!(summary_field(&lines[4], "cache_hits"), 1);
    // Identical block payloads (bit-identical replay, different id).
    assert_eq!(
        lines[0].replace("\"id\":1", "\"id\":2"),
        lines[3],
        "warm block must be bit-identical"
    );
    // The error reply is structured and carries a message.
    assert!(lines[2].contains("\"message\":"), "{}", lines[2]);
}

#[test]
fn serve_session_replies_errors_for_valid_lines_with_bad_semantics() {
    let engine = Arc::new(SweepEngine::new());
    let input = concat!(
        "{\"id\":3}\n",                         // sweep without network
        "{\"id\":4,\"network\":\"AlexNet\"}\n", // unknown network
        "{\"id\":5,\"network\":\"SqueezeNet\",\"layers\":[999]}\n", // bad subset
        "{\"id\":6,\"network\":\"SqueezeNet\",\"layers\":[1],\"lanes\":3}\n", // bad config
        "{\"id\":7,\"op\":\"ping\"}\n",
    );
    let (lines, stats) = serve_session(&engine, input);
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.errors, 4);
    assert!(!stats.shutdown, "EOF, not shutdown");
    let types: Vec<String> = lines.iter().map(|l| record_type(l)).collect();
    assert_eq!(types, vec!["error", "error", "error", "error", "pong"]);
    // Error replies echo the failing request's id.
    for (line, want) in lines.iter().zip([3u64, 4, 5, 6]) {
        assert_eq!(summary_field(line, "id"), want, "{line}");
    }
    assert_eq!(engine.cached_sims(), 0, "no sweep ever ran");
}

#[test]
fn eviction_bound_is_observable_through_a_serve_session() {
    // Server with a 1-entry cache: two distinct cells (two layer
    // shapes) evict each other, and the repeat re-simulates — the
    // `--max-cache-entries` acceptance criterion, engine-level.
    let a = Request {
        id: 1,
        network: "SqueezeNet".into(),
        layers: Some(vec![1]), // fire2_s1x1
        precisions: vec![Precision::Int8],
        strategies: vec![Strategy::FeatureFirst],
        threads: Some(1),
        ..Default::default()
    };
    let b = Request { id: 2, layers: Some(vec![2]), ..a.clone() }; // fire2_e1x1
    let a_again = Request { id: 3, ..a.clone() };
    let input =
        format!("{}\n{}\n{}\n", a.to_line(), b.to_line(), a_again.to_line());
    let engine = Arc::new(SweepEngine::new());
    engine.set_max_cache_entries(Some(1));
    let (lines, _) = serve_session(&engine, &input);
    let summaries: Vec<&String> =
        lines.iter().filter(|l| record_type(l) == "summary").collect();
    assert_eq!(summaries.len(), 3);
    assert_eq!(summary_field(summaries[0], "sims"), 1, "cold A simulates");
    assert_eq!(summary_field(summaries[1], "sims"), 1, "cold B simulates");
    assert_eq!(summary_field(summaries[1], "evictions"), 1, "B evicts A");
    assert_eq!(
        summary_field(summaries[2], "sims"),
        1,
        "A was evicted, so it must re-simulate"
    );
    assert_eq!(summary_field(summaries[2], "cache_entries"), 1);
    assert_eq!(engine.cached_sims(), 1);
    assert_eq!(engine.cache_evictions(), 2);
}

#[test]
fn engine_eviction_insert_beyond_bound_and_resimulate() {
    // Pure engine-level variant: insert > N cells, observe the
    // eviction count, then observe evicted cells re-simulating.
    let cfg = SpeedConfig::default();
    let layers: Vec<ConvLayer> = (0..5)
        .map(|i| ConvLayer::new(&format!("l{i}"), 4 + i, 4, 6, 6, 3, 1, 1))
        .collect();
    let spec = SweepSpec::new(cfg)
        .network("t", layers)
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::FeatureFirst])
        .threads(1);
    let engine = SweepEngine::new();
    engine.set_max_cache_entries(Some(3));
    let cold = engine.run(&spec).unwrap();
    assert_eq!(cold.executed_sims, 5);
    assert_eq!(cold.cache_evictions, 2, "5 inserts through a 3-entry bound");
    assert_eq!(engine.cached_sims(), 3);
    let warm = engine.run(&spec).unwrap();
    assert_eq!(warm.executed_sims, 2, "the two evicted cells re-simulate");
    assert_eq!(warm.cache_hits, 3);
    assert_eq!(warm.results, cold.results, "eviction must never change results");
}

#[test]
fn bounded_load_time_merge_respects_the_cap() {
    // Regression for the load-time merge path: a big on-disk cache
    // streamed into a bounded engine must not exceed the bound.
    let cfg = SpeedConfig::default();
    let layers: Vec<ConvLayer> = (0..6)
        .map(|i| ConvLayer::new(&format!("l{i}"), 4, 4 + i, 6, 6, 3, 1, 1))
        .collect();
    let spec = SweepSpec::new(cfg)
        .network("t", layers)
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::FeatureFirst])
        .threads(1);
    let donor = SweepEngine::new();
    donor.run(&spec).unwrap();
    assert_eq!(donor.cached_sims(), 6);
    let bytes = donor.serialize_cache();

    let bounded = SweepEngine::new();
    bounded.set_max_cache_entries(Some(2));
    let loaded = bounded.load_cache_bytes(&bytes).unwrap();
    assert_eq!(loaded, 6, "load reports the file's entry count");
    assert_eq!(bounded.cached_sims(), 2, "merge is bounded");
    assert_eq!(bounded.cache_evictions(), 4);
    // Loading the same bytes twice is deterministic (same survivors).
    let again = SweepEngine::new();
    again.set_max_cache_entries(Some(2));
    again.load_cache_bytes(&bytes).unwrap();
    assert_eq!(again.serialize_cache(), bounded.serialize_cache());
    // The bounded engine still runs the grid correctly (4 re-sims).
    let out = bounded.run(&spec).unwrap();
    assert_eq!(out.cache_hits, 2);
    assert_eq!(out.executed_sims, 4);
    assert_eq!(out.results, donor.run(&spec).unwrap().results);
}
