//! True end-to-end serve contract against the built `speed` binary:
//! stdin mode (pipe requests in, read replies out) and TCP mode
//! (`--tcp 127.0.0.1:0` + `--port-file` + `speed request`), with the
//! warm-repeat-is-pure-cache acceptance check, a malformed-request
//! error reply, graceful shutdown and a flushed cache file. Every wait
//! is bounded — a hung server fails the test instead of wedging it.

use std::io::Write;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use speed::coordinator::serve::{Op, Request};

const BIN: &str = env!("CARGO_BIN_EXE_speed");
const WAIT: Duration = Duration::from_secs(120);

/// Kill the child on scope exit so a failing test never leaks a
/// resident server.
struct Reap(Child);

impl Drop for Reap {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("speed_serve_e2e_{}_{}", tag, std::process::id()))
}

/// A tiny cold request: one small SqueezeNet layer, int8, FF.
fn tiny_request(id: u64) -> Request {
    Request {
        id,
        network: "SqueezeNet".into(),
        layers: Some(vec![1]),
        precisions: vec![speed::arch::Precision::Int8],
        strategies: vec![speed::dataflow::Strategy::FeatureFirst],
        threads: Some(1),
        ..Default::default()
    }
}

fn wait_for_exit(child: &mut Child, what: &str) -> std::process::ExitStatus {
    let deadline = Instant::now() + WAIT;
    loop {
        if let Some(status) = child.try_wait().expect("try_wait") {
            return status;
        }
        assert!(Instant::now() < deadline, "{what} hung past {WAIT:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn stdin_mode_cold_warm_malformed_and_shutdown() {
    let cache = scratch("stdin.swc");
    let _ = std::fs::remove_file(&cache);
    let child = Command::new(BIN)
        .args(["serve", "--cache-file"])
        .arg(&cache)
        .args(["--max-cache-entries", "1000"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn speed serve");
    let mut child = Reap(child);

    {
        let stdin = child.0.stdin.as_mut().expect("piped stdin");
        let script = format!(
            "{}\nmalformed line\n{}\n{}\n",
            tiny_request(1).to_line(),
            tiny_request(2).to_line(),
            Request { id: 9, op: Op::Shutdown, ..Default::default() }.to_line()
        );
        stdin.write_all(script.as_bytes()).expect("write requests");
        stdin.flush().expect("flush requests");
    }
    drop(child.0.stdin.take()); // EOF, in case shutdown is missed

    let status = wait_for_exit(&mut child.0, "stdin-mode server");
    assert!(status.success(), "serve exited with {status}");

    let mut out = String::new();
    use std::io::Read;
    child.0.stdout.take().expect("piped stdout").read_to_string(&mut out).expect("read replies");
    let lines: Vec<&str> = out.lines().collect();
    // block, summary(cold), error, block, summary(warm), bye
    assert_eq!(lines.len(), 6, "reply stream:\n{out}");
    assert!(lines[0].contains("\"type\":\"block\""), "{}", lines[0]);
    assert!(lines[1].contains("\"type\":\"summary\"") && lines[1].contains("\"sims\":1"),
        "cold summary must execute one sim: {}", lines[1]);
    assert!(lines[2].contains("\"type\":\"error\""), "{}", lines[2]);
    assert!(lines[4].contains("\"type\":\"summary\"") && lines[4].contains("\"sims\":0"),
        "warm repeat must be pure cache: {}", lines[4]);
    assert!(lines[5].contains("\"type\":\"bye\""), "{}", lines[5]);

    // Graceful shutdown flushed a loadable cache file.
    let engine = speed::coordinator::sweep::SweepEngine::new();
    let loaded = engine.load_cache(&cache).expect("flushed cache file must decode");
    assert_eq!(loaded, 1, "exactly the one simulated cell is persisted");
    let _ = std::fs::remove_file(&cache);
}

fn request_cmd(addr: &str, extra: &[&str]) -> Command {
    let mut cmd = Command::new(BIN);
    cmd.args([
        "request",
        "--tcp",
        addr,
        "--network",
        "SqueezeNet",
        "--layers",
        "1",
        "--prec",
        "8",
        "--strategy",
        "ff",
        "--threads",
        "1",
        "--timeout-secs",
        "120",
    ]);
    cmd.args(extra);
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    cmd
}

#[test]
fn tcp_mode_end_to_end_with_client_expectations() {
    let cache = scratch("tcp.swc");
    let port_file = scratch("tcp.port");
    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&port_file);

    let child = Command::new(BIN)
        .args(["serve", "--tcp", "127.0.0.1:0", "--port-file"])
        .arg(&port_file)
        .arg("--cache-file")
        .arg(&cache)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn speed serve --tcp");
    let mut child = Reap(child);

    // Discover the ephemeral port.
    let deadline = Instant::now() + WAIT;
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            if !s.trim().is_empty() {
                break s.trim().to_string();
            }
        }
        assert!(Instant::now() < deadline, "server never wrote {port_file:?}");
        assert!(
            child.0.try_wait().expect("try_wait").is_none(),
            "server exited before listening"
        );
        std::thread::sleep(Duration::from_millis(50));
    };

    // Cold request: succeeds, summary present.
    let cold = request_cmd(&addr, &["--id", "1"]).output().expect("cold request");
    assert!(cold.status.success(), "cold: {cold:?}");
    let cold_out = String::from_utf8_lossy(&cold.stdout).to_string();
    assert!(cold_out.contains("\"type\":\"summary\"") && cold_out.contains("\"sims\":1"),
        "cold reply:\n{cold_out}");

    // Warm repeat over a *new connection*: the shared engine makes it
    // pure cache; the client asserts sims == 0 itself.
    let warm = request_cmd(&addr, &["--id", "2", "--expect-sims", "0"])
        .output()
        .expect("warm request");
    assert!(
        warm.status.success(),
        "warm --expect-sims 0 failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&warm.stdout),
        String::from_utf8_lossy(&warm.stderr)
    );

    // Malformed request: a structured error reply, not a hang/exit.
    let bad = request_cmd(&addr, &["--raw", "{\"definitely\":\"not a request\"", "--expect-error"])
        .output()
        .expect("malformed request");
    assert!(bad.status.success(), "--expect-error must accept the error reply: {bad:?}");

    // The server survived the malformed line: ping still answers.
    let ping = request_cmd(&addr, &["--id", "7", "--op", "ping"]).output().expect("ping");
    assert!(ping.status.success(), "ping: {ping:?}");
    assert!(String::from_utf8_lossy(&ping.stdout).contains("\"type\":\"pong\""));

    // Shutdown: bye reply, server exit, cache file flushed.
    let shut = request_cmd(&addr, &["--id", "9", "--op", "shutdown"]).output().expect("shutdown");
    assert!(shut.status.success(), "shutdown: {shut:?}");
    assert!(String::from_utf8_lossy(&shut.stdout).contains("\"type\":\"bye\""));
    let status = wait_for_exit(&mut child.0, "tcp-mode server");
    assert!(status.success(), "serve exited with {status}");

    let engine = speed::coordinator::sweep::SweepEngine::new();
    assert_eq!(engine.load_cache(&cache).expect("flushed cache"), 1);

    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&port_file);
}
