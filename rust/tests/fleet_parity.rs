//! Fleet parity contract: `speed fleet` over N in-process TCP serve
//! nodes produces bit-identical blocks and totals to one local engine
//! answering the same request — at any node count, with cache
//! exchange on or off, and under injected failures (a node killed
//! mid-item, a node that only answers `overload`, a node fed a
//! corrupt `cache_import` blob). Every assertion is
//! timing-independent: parity and conservation sums hold under any
//! interleaving; only *which node* computed a given item varies.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::fleet::{run_fleet, FleetOptions};
use speed::coordinator::serve::{self, Op, Request, ServeLimits, ServeShared};
use speed::coordinator::sweep::SweepEngine;
use speed::dataflow::Strategy;

fn unlimited() -> ServeLimits {
    ServeLimits { max_connections: 0, max_concurrent_sweeps: 0, idle_timeout_secs: 0 }
}

/// The grid every parity test distributes: 3 distinct SqueezeNet
/// layers × 2 precisions × feature-first = 6 single-cell work items.
fn grid_request(id: u64) -> Request {
    Request {
        id,
        network: "SqueezeNet".into(),
        layers: Some(vec![1, 2, 3]),
        precisions: vec![Precision::Int8, Precision::Int4],
        strategies: vec![Strategy::FeatureFirst],
        threads: Some(1),
        ..Default::default()
    }
}

/// Reference run: one local engine answering `req` over the serve
/// layer. Returns (block lines, executed sims).
fn local_reference(req: &Request) -> (Vec<String>, u64) {
    let shared =
        ServeShared::new(Arc::new(SweepEngine::new()), SpeedConfig::default(), unlimited());
    let input = format!("{}\n", req.to_line());
    let mut out: Vec<u8> = Vec::new();
    let stats = serve::serve_lines(&shared, BufReader::new(input.as_bytes()), &mut out);
    assert_eq!(stats.errors, 0);
    let lines: Vec<String> =
        String::from_utf8(out).expect("utf-8").lines().map(String::from).collect();
    let (summary, blocks) = lines.split_last().expect("summary line");
    assert!(summary.contains("\"type\":\"summary\""), "{summary}");
    let sims = field_u64(summary, "sims");
    (blocks.to_vec(), sims)
}

fn field_u64(line: &str, key: &str) -> u64 {
    for (k, v) in serve::parse_record(line).expect("line parses") {
        if k == key {
            if let serve::Value::Int(n) = v {
                return n;
            }
            panic!("field `{key}` is not an int in {line}");
        }
    }
    panic!("missing field `{key}` in {line}");
}

/// One in-process worker node: its own engine behind the real TCP
/// accept loop.
struct Node {
    addr: String,
    shutdown: Arc<AtomicBool>,
    handle: thread::JoinHandle<serve::TcpReport>,
}

fn spawn_node() -> Node {
    let shared = Arc::new(ServeShared::new(
        Arc::new(SweepEngine::new()),
        SpeedConfig::default(),
        unlimited(),
    ));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr").to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    let handle = {
        let shared = Arc::clone(&shared);
        let shutdown = Arc::clone(&shutdown);
        thread::spawn(move || {
            serve::run_tcp(&shared, listener, None, 0, &shutdown).expect("run_tcp")
        })
    };
    Node { addr, shutdown, handle }
}

impl Node {
    fn stop(self) -> serve::TcpReport {
        self.shutdown.store(true, Ordering::SeqCst);
        self.handle.join().expect("node thread")
    }
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).expect("send");
    stream.write_all(b"\n").expect("newline");
    stream.flush().expect("flush");
}

fn read_reply(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    reader.read_line(&mut line).expect("read reply");
    line.trim_end().to_string()
}

#[test]
fn fleet_matches_local_engine_bit_for_bit_and_warms_every_node() {
    let (local_blocks, local_sims) = local_reference(&grid_request(7));
    assert_eq!(local_blocks.len(), 6);
    assert_eq!(local_sims, 6);

    let nodes: Vec<Node> = (0..2).map(|_| spawn_node()).collect();
    let opts = FleetOptions::new(
        nodes.iter().map(|n| n.addr.clone()).collect(),
        SpeedConfig::default(),
        grid_request(7),
    );

    // Cold fleet: same blocks, same ids, same order, same totals.
    let cold = run_fleet(&opts).expect("cold fleet");
    assert_eq!(cold.blocks, local_blocks, "fleet blocks must be bit-identical to local");
    assert_eq!(cold.jobs, 6);
    assert_eq!(cold.sims, local_sims, "fleet executes exactly the local sim count");
    assert_eq!(cold.requeues, 0);
    let items: u64 = cold.nodes.iter().map(|n| n.items_done).sum();
    assert_eq!(items, 6, "every item completed exactly once: {:?}", cold.nodes);
    assert!(cold.nodes.iter().all(|n| !n.dead), "{:?}", cold.nodes);
    // The post-sweep exchange pushed the union to at least one node
    // (each node computed only part of the grid).
    let pushed: u64 = cold.nodes.iter().map(|n| n.pushed_entries).sum();
    assert!(pushed > 0, "cache exchange must have warmed someone: {:?}", cold.nodes);

    // Warm fleet: every node already holds the union, so the same
    // request is pure cache everywhere — and still bit-identical.
    let warm = run_fleet(&opts).expect("warm fleet");
    assert_eq!(warm.blocks, local_blocks);
    assert_eq!(warm.sims, 0, "warm fleet must execute zero simulations");

    for n in nodes {
        let report = n.stop();
        assert_eq!(report.panicked_sessions, 0, "{report:?}");
    }
}

#[test]
fn node_killed_mid_item_is_requeued_bit_identically() {
    // The killer accepts exactly one connection, reads the request,
    // streams a non-terminal reply line and drops the connection *and*
    // the listener — every later connect is refused outright.
    let killer_listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let killer_addr = killer_listener.local_addr().expect("addr").to_string();
    // Detached on purpose: joining would hang if the accept never
    // fires; the `dead`/`requeues` assertions below prove it did.
    thread::spawn(move || {
        let (stream, _) = killer_listener.accept().expect("one victim connection");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut line = String::new();
        reader.read_line(&mut line).expect("read request");
        let mut stream = stream;
        send_line(&mut stream, "{\"type\":\"block\",\"id\":1,\"note\":\"about to die\"}");
        // stream + listener drop here: mid-reply EOF, then refusals.
    });

    let real = spawn_node();
    let (local_blocks, _) = local_reference(&grid_request(7));

    let mut opts = FleetOptions::new(
        vec![killer_addr, real.addr.clone()],
        SpeedConfig::default(),
        grid_request(7),
    );
    opts.cache_exchange = false; // the first killer connection must be a sweep item
    opts.max_node_failures = 2;
    opts.backoff_base_ms = 1;

    let out = run_fleet(&opts).expect("fleet survives the node kill");

    assert_eq!(out.blocks, local_blocks, "node loss must not perturb a single bit");
    assert_eq!(out.sims, 6);
    assert!(out.requeues >= 1, "the killed item must have been requeued: {out:?}");
    assert!(out.nodes[0].dead, "the killer node must be declared dead: {:?}", out.nodes);
    assert!(out.nodes[0].failures >= 2, "{:?}", out.nodes);
    assert!(!out.nodes[1].dead, "{:?}", out.nodes);
    assert_eq!(out.nodes[1].items_done, 6, "the survivor absorbed the whole grid");

    real.stop();
}

#[test]
fn overloaded_node_backs_off_and_items_retry_elsewhere() {
    // A node whose admission control permanently refuses: every request
    // line is answered with a terminal `overload` error on a healthy
    // connection.
    let busy_listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let busy_addr = busy_listener.local_addr().expect("addr").to_string();
    thread::spawn(move || {
        for stream in busy_listener.incoming() {
            let Ok(stream) = stream else { break };
            let mut reader = BufReader::new(stream.try_clone().expect("clone"));
            let mut writer = stream;
            let mut line = String::new();
            while reader.read_line(&mut line).map(|n| n > 0).unwrap_or(false) {
                let id = Request::parse(line.trim()).map(|r| r.id).unwrap_or(0);
                send_line(
                    &mut writer,
                    &serve::error_line_with_code(id, "overload", "permanently busy"),
                );
                line.clear();
            }
        }
    });

    let real = spawn_node();
    let (local_blocks, _) = local_reference(&grid_request(7));

    let mut opts = FleetOptions::new(
        vec![busy_addr, real.addr.clone()],
        SpeedConfig::default(),
        grid_request(7),
    );
    opts.cache_exchange = false;
    opts.max_node_failures = 3;
    opts.backoff_base_ms = 1;

    let out = run_fleet(&opts).expect("fleet routes around the overloaded node");
    assert_eq!(out.blocks, local_blocks);
    assert_eq!(out.sims, 6);
    assert!(out.requeues >= 1, "{out:?}");
    assert!(out.nodes[0].overloads >= 1, "{:?}", out.nodes);
    assert_eq!(out.nodes[0].items_done, 0, "{:?}", out.nodes);
    assert_eq!(out.nodes[1].items_done, 6, "{:?}", out.nodes);

    real.stop();
}

#[test]
fn corrupt_cache_import_is_rejected_without_poisoning_the_node() {
    let node = spawn_node();
    let stream = TcpStream::connect(&node.addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;

    // Garbage hex and valid-hex-garbage-bytes both reject atomically.
    for blob in ["zz", "deadbeef"] {
        let req = Request {
            id: 1,
            op: Op::CacheImport,
            blob: Some(blob.into()),
            ..Default::default()
        };
        send_line(&mut stream, &req.to_line());
        let reply = read_reply(&mut reader);
        assert!(reply.contains("\"type\":\"error\""), "{reply}");
        assert!(reply.contains("\"code\":\"bad_blob\""), "{reply}");
    }

    // The node is not poisoned: a sweep on the same connection still
    // simulates from a clean cache and exports a healthy blob.
    send_line(
        &mut stream,
        &Request {
            id: 2,
            network: "SqueezeNet".into(),
            layers: Some(vec![1]),
            precisions: vec![Precision::Int8],
            strategies: vec![Strategy::FeatureFirst],
            threads: Some(1),
            ..Default::default()
        }
        .to_line(),
    );
    let block = read_reply(&mut reader);
    assert!(block.contains("\"type\":\"block\""), "{block}");
    let summary = read_reply(&mut reader);
    assert_eq!(field_u64(&summary, "sims"), 1, "{summary}");

    send_line(
        &mut stream,
        &Request { id: 3, op: Op::CacheExport, ..Default::default() }.to_line(),
    );
    let cache = read_reply(&mut reader);
    assert!(cache.contains("\"type\":\"cache\""), "{cache}");
    assert_eq!(field_u64(&cache, "entries"), 1, "{cache}");

    drop(stream);
    drop(reader);
    node.stop();
}

#[test]
fn losing_every_node_fails_with_work_outstanding() {
    // A dead address: bind, learn the port, close the listener.
    let addr = {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind");
        l.local_addr().expect("addr").to_string()
    };
    let mut opts =
        FleetOptions::new(vec![addr], SpeedConfig::default(), grid_request(7));
    opts.cache_exchange = false;
    opts.max_node_failures = 2;
    opts.backoff_base_ms = 1;
    let err = run_fleet(&opts).expect_err("no nodes, no fleet");
    let msg = err.to_string();
    assert!(msg.contains("all nodes lost"), "{msg}");
}
