//! The parallel sweep engine's contract: for any thread count, for any
//! cache state, results are **bit-identical** to the serial single-layer
//! API. Checked over randomized layer grids (2 seeds × 3 thread counts)
//! plus propcheck properties for the memoization cache.

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::simulate_layer;
use speed::coordinator::sweep::{SweepEngine, SweepSpec};
use speed::dataflow::{ConvLayer, Strategy};
use speed::testutil::{check, PropConfig, Prng};

/// A small random network; always contains one duplicated shape so the
/// dedup path is exercised on every run.
fn random_layers(rng: &mut Prng) -> Vec<ConvLayer> {
    let mut layers = Vec::new();
    for i in 0..4 {
        let k = *rng.pick(&[1usize, 3]);
        let hw = rng.range_usize(k.max(4), 12);
        layers.push(ConvLayer::new(
            &format!("l{i}"),
            rng.range_usize(1, 16),
            rng.range_usize(1, 16),
            hw,
            hw,
            k,
            *rng.pick(&[1usize, 2]),
            k / 2,
        ));
    }
    // duplicate the first layer's shape under a new name
    let mut dup = layers[0].clone();
    dup.name = "dup0".to_string();
    layers.push(dup);
    layers
}

#[test]
fn parallel_results_are_bit_identical_to_serial() {
    let cfg = SpeedConfig::default();
    let precs = [Precision::Int8, Precision::Int16];
    let strats = [Strategy::FeatureFirst, Strategy::Mixed];
    for seed in [0xA1u64, 0xB2] {
        let layers = random_layers(&mut Prng::new(seed));
        // serial reference: the existing per-layer entry point, in the
        // engine's job-enumeration order (prec → strat → layer)
        let mut want = Vec::new();
        for &p in &precs {
            for &s in &strats {
                for l in &layers {
                    want.push(simulate_layer(&cfg, l, p, s).unwrap());
                }
            }
        }
        for threads in [1usize, 2, 4] {
            let spec = SweepSpec::new(cfg.clone())
                .network("rand", layers.clone())
                .precisions(precs.to_vec())
                .strategies(strats.to_vec())
                .threads(threads);
            let out = SweepEngine::new().run(&spec).unwrap();
            assert_eq!(
                out.results, want,
                "seed {seed:#x}, {threads} threads: engine diverged from serial"
            );
        }
    }
}

#[test]
fn thread_count_never_changes_the_outcome() {
    // engine-vs-engine across thread counts, including the block view
    let cfg = SpeedConfig::default();
    let layers = random_layers(&mut Prng::new(0xC3));
    let spec_for = |threads: usize| {
        SweepSpec::new(cfg.clone())
            .network("rand", layers.clone())
            .precisions(vec![Precision::Int4])
            .strategies(vec![Strategy::Mixed])
            .threads(threads)
    };
    let base = SweepEngine::new().run(&spec_for(1)).unwrap();
    for threads in [2usize, 4] {
        let out = SweepEngine::new().run(&spec_for(threads)).unwrap();
        assert_eq!(out.results, base.results, "{threads} threads");
        assert_eq!(out.block(0, 0, 0, 0, 0), base.block(0, 0, 0, 0, 0));
    }
}

#[test]
fn cache_hits_never_change_cycles_or_gops() {
    // Property: (a) a duplicated shape served by the intra-run dedup and
    // (b) a warm persistent cache both report exactly the cycles/gops of
    // a fresh simulation.
    let cfg = SpeedConfig::default();
    check(PropConfig::new(8, 0xCAFE), |rng| {
        let k = *rng.pick(&[1usize, 3]);
        let hw = rng.range_usize(k.max(4), 10);
        let layer = ConvLayer::new(
            "a",
            rng.range_usize(1, 12),
            rng.range_usize(1, 12),
            hw,
            hw,
            k,
            1,
            k / 2,
        );
        let mut twin = layer.clone();
        twin.name = "b".to_string();
        let p = *rng.pick(&Precision::ALL);
        let s = *rng.pick(&[Strategy::FeatureFirst, Strategy::ChannelFirst, Strategy::Mixed]);
        let spec = SweepSpec::new(cfg.clone())
            .network("prop", vec![layer.clone(), twin])
            .precisions(vec![p])
            .strategies(vec![s])
            .threads(1);
        let engine = SweepEngine::new();
        let cold = engine.run(&spec).map_err(|e| e.to_string())?;
        let fresh = simulate_layer(&cfg, &layer, p, s).map_err(|e| e.to_string())?;
        let (a, b) = (&cold.results[0], &cold.results[1]);
        if a.cycles != fresh.cycles || a.stats.gops(cfg.freq_mhz) != fresh.stats.gops(cfg.freq_mhz)
        {
            return Err(format!("{layer} {p} {s}: engine != serial"));
        }
        if b.cycles != a.cycles || b.stats.gops(cfg.freq_mhz) != a.stats.gops(cfg.freq_mhz) {
            return Err(format!("{layer} {p} {s}: dedup hit changed the numbers"));
        }
        // warm rerun: pure cache must reproduce everything
        let warm = engine.run(&spec).map_err(|e| e.to_string())?;
        if warm.executed_sims != 0 {
            return Err("warm rerun executed simulations".to_string());
        }
        if warm.results != cold.results {
            return Err(format!("{layer} {p} {s}: cache hit changed the results"));
        }
        Ok(())
    });
}

#[test]
fn simulate_network_matches_per_layer_calls() {
    let cfg = SpeedConfig::default();
    let layers = random_layers(&mut Prng::new(0xD4));
    let net =
        speed::coordinator::simulate_network(&cfg, "n", &layers, Precision::Int8, Strategy::Mixed)
            .unwrap();
    assert_eq!(net.layers.len(), layers.len());
    for (l, got) in layers.iter().zip(&net.layers) {
        let want = simulate_layer(&cfg, l, Precision::Int8, Strategy::Mixed).unwrap();
        assert_eq!(*got, want, "{l}");
    }
    assert!(net.total_cycles() > 0 && net.gops(cfg.freq_mhz) > 0.0);
}
