//! Fast-forward parity contract: loop-aware steady-state fast-forward
//! must produce **bit-identical** `SimStats` to step-by-step execution
//! over the benchmark grid — every network × {16, 8, 4}-bit ×
//! {FF, CF, Mixed}. The default test covers every zoo network through
//! its cheapest layers (plus a decomposable layer so shard fan-out and
//! fast-forward compose); the `#[ignore]`d variant steps the *entire*
//! benchmark grid twice and is run by CI's weekly full-grid job.
//!
//! A second contract rides along: a deliberately irregular program
//! region (its per-iteration timing delta never converges) must fall
//! back to full stepping — pinned at the processor level in
//! `core::processor::tests::irregular_region_falls_back_to_stepping`
//! and re-checked here through the public API.

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::sweep::{SweepEngine, SweepSpec, SHARD_OFF};
use speed::core::{ExecMode, Processor};
use speed::dataflow::{ConvLayer, Strategy};
use speed::isa::{Instr, Program, Region};
use speed::models::all_models;

/// The full comparison axes of the contract.
fn axes(spec: SweepSpec) -> SweepSpec {
    spec.precisions(vec![Precision::Int16, Precision::Int8, Precision::Int4]).strategies(vec![
        Strategy::FeatureFirst,
        Strategy::ChannelFirst,
        Strategy::Mixed,
    ])
}

/// Run the grid with fast-forward on and off (fresh engines, so both
/// actually simulate) and require bit-identical results.
fn assert_parity(spec: &SweepSpec, expect_skips: bool) {
    let on = SweepEngine::new().run(spec).expect("fast-forward sweep");
    let off =
        SweepEngine::new().run(&spec.clone().fast_forward(false)).expect("stepped sweep");
    assert_eq!(
        on.results, off.results,
        "fast-forward must not move a single cycle anywhere in the grid"
    );
    assert_eq!(off.fast_forwarded_instrs, 0, "disabled fast-forward must step everything");
    if expect_skips {
        assert!(
            on.fast_forwarded_instrs > 0,
            "the grid must actually exercise fast-forward"
        );
    }
}

/// Every network, represented by its cheapest layers (capped per
/// network so the doubled grid stays test-suite affordable), plus one
/// decomposable layer exercising shard × fast-forward composition.
#[test]
fn representative_grid_is_bit_identical() {
    let mut spec = axes(SweepSpec::new(SpeedConfig::default()));
    for m in all_models() {
        let mut layers = m.layers;
        layers.sort_by_key(|l| l.macs());
        layers.truncate(2);
        spec = spec.network(m.name, layers);
    }
    spec = spec.network("shardable", vec![ConvLayer::new("big", 64, 64, 30, 30, 3, 1, 1)]);
    assert_parity(&spec, true);
}

/// Shard fan-out disabled entirely: the inline shard composition path
/// must agree with itself under fast-forward too.
#[test]
fn unsharded_composition_is_bit_identical() {
    let spec = axes(SweepSpec::new(SpeedConfig::default()))
        .network("shardable", vec![ConvLayer::new("big", 64, 64, 30, 30, 3, 1, 1)])
        .shard_threshold(SHARD_OFF)
        .threads(1);
    assert_parity(&spec, true);
}

/// Delta-cache parity contract: converged-delta replay must be
/// bit-identical to full convergence over an every-network grid at two
/// thread counts, cold *and* warm (the warm pass is where cached
/// deltas actually replay). Memoization is off so the warm pass
/// re-simulates every cell instead of answering from the memo table.
#[test]
fn delta_cache_is_bit_identical_across_thread_counts() {
    let mut base = axes(SweepSpec::new(SpeedConfig::default())).memoize(false);
    for m in all_models() {
        let mut layers = m.layers;
        layers.sort_by_key(|l| l.macs());
        layers.truncate(1);
        base = base.network(m.name, layers);
    }
    base = base.network("shardable", vec![ConvLayer::new("big", 64, 64, 30, 30, 3, 1, 1)]);
    for threads in [1usize, 4] {
        let spec = base.clone().threads(threads);
        let engine = SweepEngine::new();
        let cold = engine.run(&spec).expect("delta-on cold sweep");
        let warm = engine.run(&spec).expect("delta-on warm sweep");
        let off = SweepEngine::new()
            .run(&spec.clone().delta_cache(false))
            .expect("delta-off sweep");
        assert_eq!(
            cold.results, off.results,
            "{threads} threads: delta cache moved a cycle on the cold pass"
        );
        assert_eq!(
            warm.results, off.results,
            "{threads} threads: delta replay moved a cycle on the warm pass"
        );
        assert_eq!(off.delta_cache_hits, 0, "{threads} threads: disabled cache must not hit");
        assert!(engine.cached_deltas() > 0, "{threads} threads: no deltas were published");
        assert!(
            warm.delta_cache_hits > 0,
            "{threads} threads: the warm pass must actually replay cached deltas"
        );
        assert!(
            warm.fast_forwarded_instrs >= cold.fast_forwarded_instrs,
            "{threads} threads: replay must never step more than full convergence"
        );
    }
}

/// The paper's entire benchmark grid, stepped twice (fast-forward on
/// vs off). Minutes of simulation — weekly CI (`cargo test -- --ignored`).
#[test]
#[ignore = "full benchmark grid twice (fast-forward on vs off) — minutes in a debug build"]
fn full_benchmark_grid_is_bit_identical() {
    let mut spec = axes(SweepSpec::new(SpeedConfig::default()));
    for m in all_models() {
        spec = spec.network(m.name, m.layers);
    }
    assert_parity(&spec, true);
}

/// Public-API form of the irregular-region fallback: a region whose
/// iterations change the vector length can never converge, so
/// fast-forward must step it — identical stats, nothing skipped.
#[test]
fn irregular_region_steps_through_the_public_api() {
    let build = || {
        let mut b = Program::builder();
        let mut marks = Vec::new();
        for it in 0..6u32 {
            marks.push(b.len());
            b.set_vl(8 * (it + 1), 8, 1);
            b.emit(Instr::VaddVv { vd: 3, vs2: 1, vs1: 2 });
        }
        marks.push(b.len());
        let mut p = b.build();
        for r in Region::steady_runs(&marks, 3) {
            p.push_region(r);
        }
        assert!(!p.regions().is_empty());
        p
    };
    let mut fast = Processor::new(SpeedConfig::default(), 1 << 16, ExecMode::Timing).unwrap();
    fast.run(&build()).unwrap();
    assert_eq!(fast.fast_forwarded_instrs(), 0, "irregular region must not extrapolate");
    let mut slow = Processor::new(SpeedConfig::default(), 1 << 16, ExecMode::Timing).unwrap();
    slow.set_fast_forward(false);
    slow.run(&build()).unwrap();
    assert_eq!(fast.stats(), slow.stats());
}
