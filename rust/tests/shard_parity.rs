//! Intra-layer sharding parity contract: for every backend that
//! shards, a decomposable layer's result is **bit-identical** however
//! the shards are grouped into sub-jobs ({1, 2, 4, 7} groups), however
//! many worker threads execute them ({1, 4}), and however the shard
//! results arrive (merge is completion-order independent) — all equal
//! to the unsharded (inline) run and to the serial single-layer API.

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::backend::{by_name, SimBackend, SpeedCycle, WorkerSlot, BACKEND_NAMES};
use speed::coordinator::simulate_layer;
use speed::coordinator::sweep::{SweepEngine, SweepSpec, SHARD_AUTO_MACS, SHARD_OFF};
use speed::core::SimStats;
use speed::dataflow::{ConvLayer, Strategy};

/// Smallest comfortably-decomposable layer: just over the dataflow
/// layer's decomposition bound, so the parity matrix stays cheap.
fn big_layer() -> ConvLayer {
    ConvLayer::new("big", 64, 64, 30, 30, 3, 1, 1)
}

fn atom_stats(backend: &dyn SimBackend, cfg: &SpeedConfig, layer: &ConvLayer) -> Vec<SimStats> {
    let shards = backend.shard_layout(cfg, layer).expect("layer decomposes");
    let mut slot = WorkerSlot::default();
    shards
        .iter()
        .map(|sh| {
            backend
                .simulate_shard(&mut slot, cfg, layer, Precision::Int8, Strategy::FeatureFirst, sh)
                .expect("shard simulates")
        })
        .collect()
}

fn merge_all<'a>(stats: impl Iterator<Item = &'a SimStats>) -> SimStats {
    let mut total = SimStats::default();
    for s in stats {
        total.merge(s);
    }
    total
}

#[test]
fn any_shard_grouping_is_bit_identical() {
    // Group the fixed shard decomposition into {1, 2, 4, 7} contiguous
    // sub-jobs; each sub-job merges its own shards, the groups merge in
    // order. Every grouping must reproduce the backend's own composed
    // result exactly — the property that lets the engine pick sub-job
    // granularity freely (and cache at layer level) without changing a
    // single bit.
    let cfg = SpeedConfig::default();
    let layer = big_layer();
    let atoms = atom_stats(&SpeedCycle, &cfg, &layer);
    assert!(atoms.len() >= 7, "need >= 7 shards for the grouping matrix");
    let whole = SpeedCycle
        .simulate(&mut WorkerSlot::default(), &cfg, &layer, Precision::Int8, Strategy::FeatureFirst)
        .unwrap();
    for groups in [1usize, 2, 4, 7] {
        let per = atoms.len().div_ceil(groups);
        let grouped: Vec<SimStats> =
            atoms.chunks(per).map(|chunk| merge_all(chunk.iter())).collect();
        assert!(grouped.len() <= groups.max(1));
        let total = merge_all(grouped.iter());
        assert_eq!(total, whole, "{groups} groups diverged from the composed result");
    }
    assert_eq!(whole.useful_macs, layer.macs());
}

#[test]
fn shard_merge_is_completion_order_independent() {
    // Workers finish in arbitrary order; the merge must not care. The
    // engine merges in shard-index order regardless, but this pins the
    // stronger property the scheduling relies on: the composition is a
    // per-field sum, so *any* arrival order gives the same bits.
    let cfg = SpeedConfig::default();
    let layer = big_layer();
    let atoms = atom_stats(&SpeedCycle, &cfg, &layer);
    let inorder = merge_all(atoms.iter());
    let n = atoms.len();
    // A few deterministic permutations: reversed, odds-then-evens, and
    // a stride walk.
    let reversed: Vec<usize> = (0..n).rev().collect();
    let odds_evens: Vec<usize> =
        (0..n).filter(|i| i % 2 == 1).chain((0..n).filter(|i| i % 2 == 0)).collect();
    let stride: Vec<usize> = (0..5).flat_map(|r| (r..n).step_by(5)).collect();
    for (label, perm) in
        [("reversed", reversed), ("odds-then-evens", odds_evens), ("stride-5", stride)]
    {
        assert_eq!(perm.len(), n, "{label}: bad permutation");
        let shuffled = merge_all(perm.iter().map(|&i| &atoms[i]));
        assert_eq!(shuffled, inorder, "{label}: completion order changed the merge");
    }
}

#[test]
fn engine_parity_across_fanout_and_threads() {
    // The engine path end-to-end: fanned out at {1, 4} threads and
    // inline (fan-out off) must emit bit-identical LayerResults, equal
    // to the serial API.
    let cfg = SpeedConfig::default();
    let layer = big_layer();
    let serial = simulate_layer(&cfg, &layer, Precision::Int8, Strategy::FeatureFirst).unwrap();
    let spec_for = |threshold: u64, threads: usize| {
        SweepSpec::new(cfg.clone())
            .network("t", vec![layer.clone()])
            .precisions(vec![Precision::Int8])
            .strategies(vec![Strategy::FeatureFirst])
            .shard_threshold(threshold)
            .threads(threads)
    };
    for threads in [1usize, 4] {
        let fanned = SweepEngine::new().run(&spec_for(SHARD_AUTO_MACS, threads)).unwrap();
        assert_eq!(fanned.sharded_jobs, 1, "{threads} threads");
        assert!(fanned.shards_spawned > 1, "{threads} threads");
        assert_eq!(fanned.results[0], serial, "{threads} threads: fanned != serial");
    }
    let inline = SweepEngine::new().run(&spec_for(SHARD_OFF, 4)).unwrap();
    assert_eq!(inline.shards_spawned, 0);
    assert_eq!(inline.results[0], serial, "inline != serial");
}

#[test]
fn work_order_is_result_invariant() {
    // The engine claims work items in LPT order (heaviest estimated
    // MACs first). That is pure scheduling: results are keyed by job
    // identity, so enumerating the same jobs in any order — here the
    // layer list forwards vs reversed, mixing a decomposable layer
    // with small ones, at 1 and 4 threads — must produce the same
    // per-layer bits.
    let cfg = SpeedConfig::default();
    let layers = vec![
        ConvLayer::new("tiny", 8, 8, 8, 8, 3, 1, 1),
        big_layer(),
        ConvLayer::new("pw", 16, 8, 6, 6, 1, 1, 0),
        ConvLayer::new("mid", 32, 32, 14, 14, 3, 1, 1),
    ];
    let spec_for = |layers: Vec<ConvLayer>, threads: usize| {
        SweepSpec::new(cfg.clone())
            .network("t", layers)
            .precisions(vec![Precision::Int8])
            .strategies(vec![Strategy::FeatureFirst])
            .shard_threshold(SHARD_AUTO_MACS)
            .threads(threads)
    };
    let mut reversed_layers = layers.clone();
    reversed_layers.reverse();
    let forward = SweepEngine::new().run(&spec_for(layers.clone(), 4)).unwrap();
    assert_eq!(forward.sharded_jobs, 1, "the big layer must fan out");
    for threads in [1usize, 4] {
        let reversed =
            SweepEngine::new().run(&spec_for(reversed_layers.clone(), threads)).unwrap();
        for r in &forward.results {
            let mate = reversed
                .results
                .iter()
                .find(|o| o.name == r.name)
                .expect("same jobs under any enumeration order");
            assert_eq!(mate, r, "{threads} threads: enqueue order changed `{}`", r.name);
        }
    }

    // Wavefront ordering interleaves DRAM-bound and compute-bound work
    // by roofline class — still pure scheduling. Pin it with a layer
    // set that definitely lands in both classes: deep 3x3 convolutions
    // (compute-bound) against large-spatial pointwise ones
    // (DRAM-bound), forwards vs reversed at 1 and 4 threads.
    let wave_layers = vec![
        ConvLayer::new("deep3x3", 64, 64, 14, 14, 3, 1, 1),
        ConvLayer::new("pw_wide", 16, 16, 56, 56, 1, 1, 0),
        ConvLayer::new("mid3x3", 32, 32, 28, 28, 3, 1, 1),
        ConvLayer::new("pw_mid", 8, 16, 40, 40, 1, 1, 0),
    ];
    let mut wave_reversed = wave_layers.clone();
    wave_reversed.reverse();
    let wavefront = SweepEngine::new().run(&spec_for(wave_layers, 4)).unwrap();
    for threads in [1usize, 4] {
        let rev = SweepEngine::new().run(&spec_for(wave_reversed.clone(), threads)).unwrap();
        for r in &wavefront.results {
            let mate = rev
                .results
                .iter()
                .find(|o| o.name == r.name)
                .expect("same jobs under any enumeration order");
            assert_eq!(mate, r, "{threads} threads: wavefront order changed `{}`", r.name);
        }
    }
}

#[test]
fn every_sharding_backend_is_pinned() {
    // The parity matrix above must cover every registered backend that
    // decomposes layers: if a new backend starts sharding, this fails
    // until the parity tests learn about it.
    let cfg = SpeedConfig::default();
    let layer = big_layer();
    for name in BACKEND_NAMES {
        let b = by_name(name).unwrap();
        let shards = b.shard_layout(&cfg, &layer);
        assert_eq!(
            shards.is_some(),
            name == "speed",
            "backend `{name}`: sharding support changed — extend shard_parity.rs"
        );
    }
}
