//! The repo's central cross-layer proof: the Rust cycle-accurate
//! *functional* simulator, the host reference conv, and the XLA/PJRT
//! golden (lowered from the JAX+Pallas bit-split kernel) all agree
//! **bit-exactly** on the same tensors.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::run_functional_conv;
use speed::dataflow::{ConvLayer, Strategy};
use speed::mem::tensor::conv2d_ref;
use speed::mem::Tensor;
use speed::pe::combine::dot_unified;
use speed::runtime::golden::{ConvGolden, GemmGolden, CONV1X1_I8, CONV3X3_I16, CONV3X3_I4, CONV3X3_I8};
use speed::runtime::{PjrtRuntime, GEMM_K, GEMM_M, GEMM_N};
use speed::testutil::Prng;

fn artifact_dir() -> Option<std::path::PathBuf> {
    if !cfg!(all(feature = "xla", xla_vendored)) {
        eprintln!("SKIP: no XLA client in this build — PJRT runtime is a stub");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn gemm_golden_matches_pe_model_all_precisions() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = PjrtRuntime::new(dir).unwrap();
    for p in Precision::ALL {
        let mut rng = Prng::new(0xA0 + p.bits() as u64);
        let a: Vec<i64> = rng.signed_vec(p.bits(), GEMM_M * GEMM_K);
        let b: Vec<i64> = rng.signed_vec(p.bits(), GEMM_N * GEMM_K);
        let a32: Vec<i32> = a.iter().map(|&v| v as i32).collect();
        let b32: Vec<i32> = b.iter().map(|&v| v as i32).collect();
        let got = GemmGolden::new(&mut rt, p).run(&a32, &b32).unwrap();
        // reference via the PE nibble arithmetic (same math as the SAU)
        for m in 0..GEMM_M {
            for n in 0..GEMM_N {
                let mut acc = 0i32;
                for kc in (0..GEMM_K).step_by(p.group()) {
                    let g = p.group().min(GEMM_K - kc);
                    let av = &a[m * GEMM_K + kc..m * GEMM_K + kc + g];
                    let bv = &b[n * GEMM_K + kc..n * GEMM_K + kc + g];
                    if g == p.group() {
                        acc = acc.wrapping_add(dot_unified(p, av, bv));
                    } else {
                        for i in 0..g {
                            acc = acc.wrapping_add((av[i] * bv[i]) as i32);
                        }
                    }
                }
                assert_eq!(got[m * GEMM_N + n], acc, "{p} at ({m},{n})");
            }
        }
    }
}

#[test]
fn conv_golden_matches_functional_simulator() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = PjrtRuntime::new(dir).unwrap();
    let cfg = SpeedConfig::default();
    for spec in [CONV3X3_I8, CONV1X1_I8, CONV3X3_I4, CONV3X3_I16] {
        let p = spec.precision;
        let mut rng = Prng::new(0xC0 + spec.k as u64);
        let input = Tensor::random(&[spec.cin, spec.hw, spec.hw], p, &mut rng);
        let weights = Tensor::random(&[spec.cout, spec.cin, spec.k, spec.k], p, &mut rng);

        // 1) XLA golden (Pallas bit-split kernel, AOT-lowered)
        let golden = ConvGolden::new(&mut rt, spec).run(&input, &weights).unwrap();

        // 2) host reference
        let reference =
            conv2d_ref(&input, &weights, p, spec.stride, spec.pad, spec.shift, spec.relu);
        assert_eq!(golden.shape, reference.shape, "{}", spec.artifact);
        assert_eq!(golden.data, reference.data, "{}: golden vs host ref", spec.artifact);

        // 3) cycle-accurate functional simulator, both dataflows
        let layer = ConvLayer::new(
            "golden",
            spec.cin,
            spec.cout,
            spec.hw,
            spec.hw,
            spec.k,
            spec.stride,
            spec.pad,
        );
        for strat in [Strategy::ChannelFirst, Strategy::FeatureFirst] {
            let sim = run_functional_conv(
                &cfg, &layer, p, strat, &input, &weights, spec.shift, spec.relu,
            )
            .unwrap();
            assert_eq!(
                sim.data, golden.data,
                "{}: simulator({strat}) vs XLA golden",
                spec.artifact
            );
        }
    }
}
