//! Failure-injection tests: malformed programs, impossible layers and
//! resource violations must produce errors, never wrong numbers or
//! panics.

use speed::arch::{Precision, SpeedConfig};
use speed::core::{ExecMode, Processor};
use speed::dataflow::{compile_conv, ConvLayer, Strategy, TilingPlan};
use speed::isa::{assemble, decode, Program};
use speed::mem::Dram;

#[test]
fn corrupted_words_are_rejected_not_misdecoded() {
    // flip bits in a valid program; every word either decodes to a valid
    // instruction or errors — never panics.
    let layer = ConvLayer::new("t", 8, 8, 8, 8, 3, 1, 1);
    let cc = compile_conv(
        &SpeedConfig::default(),
        &layer,
        Precision::Int8,
        Strategy::ChannelFirst,
        0,
        false,
    )
    .unwrap();
    let mut rng = speed::testutil::Prng::new(99);
    for &w in cc.program.words().iter().take(500) {
        let corrupted = w ^ (1 << rng.range_usize(0, 31));
        let _ = decode(corrupted); // Ok or Err are both fine; no panic
    }
}

#[test]
fn impossible_layers_are_mapping_errors() {
    let cfg = SpeedConfig::default();
    // kernel larger than padded input
    let too_big = ConvLayer::new("k9", 4, 4, 4, 4, 9, 1, 0);
    assert!(TilingPlan::new(&cfg, &too_big, Precision::Int8, Strategy::ChannelFirst).is_err());
    // degenerate channel counts
    let zero_c = ConvLayer::new("c0", 0, 4, 8, 8, 3, 1, 1);
    assert!(TilingPlan::new(&cfg, &zero_c, Precision::Int8, Strategy::FeatureFirst).is_err());
    // TILE_H field overflow (stride 16 × K 9 ⇒ tile_h 57 is fine; 32× K
    // pushes past 63)
    let huge_stride = ConvLayer::new("s", 4, 4, 700, 700, 9, 32, 0);
    assert!(
        TilingPlan::new(&cfg, &huge_stride, Precision::Int8, Strategy::ChannelFirst).is_err()
    );
}

#[test]
fn runaway_programs_hit_memory_bounds() {
    // a program that loads from far beyond the DRAM allocation must
    // fail with a simulation error in functional mode
    let cfg = SpeedConfig::default();
    let mut m = Processor::new(cfg, 4096, ExecMode::Functional).unwrap();
    let src = r#"
        vsacfg e8, cf, th4
        addi t6, zero, 64
        vsetvli zero, t6, e16, m8
        lui a0, 0x10
        vsald.b v0, (a0)
    "#;
    let mut prog = Program::new();
    for i in assemble(src).unwrap() {
        prog.push(i);
    }
    assert!(m.run(&prog).is_err(), "OOB load must be reported");
}

#[test]
fn acc_bank_out_of_range_is_reported() {
    let cfg = SpeedConfig::default();
    let mut m = Processor::new(cfg, 1 << 16, ExecMode::Timing).unwrap();
    let src = r#"
        vsacfg e8, cf, th4
        addi t6, zero, 4
        vsetvli zero, t6, e16, m8
        vsam.macz acc31, v0, v8
    "#;
    let mut prog = Program::new();
    for i in assemble(src).unwrap() {
        prog.push(i);
    }
    assert!(m.run(&prog).is_err(), "acc bank 31 must be out of range");
}

#[test]
fn dram_allocator_exhaustion_is_an_error() {
    let mut d = Dram::new(1024, 16.0, 10);
    assert!(d.alloc(512).is_ok());
    assert!(d.alloc(1024).is_err());
}

#[test]
fn invalid_configs_never_build_processors() {
    let mut cfg = SpeedConfig::default();
    cfg.n_lanes = 3; // not a power of two
    assert!(Processor::new(cfg, 1024, ExecMode::Timing).is_err());
}
