//! Whole-program summary replay: engine-level parity and soundness.
//!
//! The contract under test: with the program-summary cache on, a
//! repeat-shape simulation reconstructs its final machine state from
//! the recorded segment deltas — zero stepped instructions — and the
//! result is **bit-identical** to stepping. Soundness comes from the
//! trust protocol (a summary only replays after a bit-exact shadow
//! validation pass) and from strict decoding of persisted summaries.
//!
//! Coverage:
//! * summary on/off bit-identity across networks × {1,4} threads ×
//!   {sharded, unsharded}, with the record → validate → replay
//!   telemetry asserted at each step;
//! * a poisoned recorded summary is discarded by shadow validation —
//!   the stepped result wins and the entry re-earns trust;
//! * a trusted summary persisted through the cache blob replays
//!   immediately after reload into a fresh engine;
//! * a corrupt v3 summary section rejects the whole blob and the
//!   engine falls back cold;
//! * a version-2 blob (pre-summary) still loads, with zero summaries.

use speed::arch::{Precision, SpeedConfig};
use speed::coordinator::backend::{fp_bytes, FP_SEED};
use speed::coordinator::sweep::{SweepEngine, SweepSpec, SHARD_AUTO_MACS, SHARD_OFF};
use speed::core::ProgramSummary;
use speed::dataflow::{ConvLayer, Strategy};

/// A layer with real steady-state loops but under the 32M-MAC shard
/// decomposition floor: one program, one summary key.
fn steady_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("steady", 16, 32, 40, 40, 3, 1, 1),
        ConvLayer::new("pw", 8, 12, 6, 6, 1, 1, 0),
    ]
}

/// A single layer just over the decomposition floor, so an auto shard
/// threshold fans it out into shard sub-programs.
fn fanout_layers() -> Vec<ConvLayer> {
    vec![ConvLayer::new("big", 64, 64, 30, 30, 3, 1, 1)]
}

/// Build the grid spec: memoization off, so every run re-simulates and
/// the summary protocol (not the memo table) carries the repeats.
fn spec_for(
    layers: &[ConvLayer],
    threads: usize,
    shard_threshold: u64,
    summary_on: bool,
) -> SweepSpec {
    SweepSpec::new(SpeedConfig::default())
        .network("t", layers.to_vec())
        .precisions(vec![Precision::Int8])
        .strategies(vec![Strategy::Mixed])
        .memoize(false)
        .threads(threads)
        .shard_threshold(shard_threshold)
        .summary_cache(summary_on)
}

#[test]
fn summary_replay_is_bit_identical_across_threads_and_sharding() {
    for layers in [steady_layers(), fanout_layers()] {
        // Reference: summary cache off, serial, unsharded.
        let off_engine = SweepEngine::new();
        let reference = off_engine.run(&spec_for(&layers, 1, SHARD_OFF, false)).unwrap();
        assert_eq!(
            (reference.summary_hits, reference.summary_replays, reference.shadow_validations),
            (0, 0, 0),
            "summary cache off must report zero summary telemetry"
        );
        assert_eq!(off_engine.cached_summaries(), 0, "off runs must record nothing");

        for threads in [1usize, 4] {
            for shard_threshold in [SHARD_AUTO_MACS, SHARD_OFF] {
                let tag = format!(
                    "{} layers, {threads} threads, shard {}",
                    layers.len(),
                    if shard_threshold == SHARD_OFF { "off" } else { "auto" },
                );
                let spec = spec_for(&layers, threads, shard_threshold, true);
                let engine = SweepEngine::new();
                // Run 1: cold — steps fully, records untrusted summaries.
                let cold = engine.run(&spec).unwrap();
                assert_eq!(cold.results, reference.results, "cold parity ({tag})");
                assert!(engine.cached_summaries() > 0, "cold run must record ({tag})");
                // Run 2: shadow validation — steps fully, compares
                // bit-exactly, and publishes (trusts) the recordings.
                let validated = engine.run(&spec).unwrap();
                assert_eq!(validated.results, reference.results, "shadow parity ({tag})");
                // Run 3: trusted summaries — pure arithmetic replay.
                let warm = engine.run(&spec).unwrap();
                assert_eq!(warm.results, reference.results, "replay parity ({tag})");
                assert!(warm.summary_replays > 0, "run 3 must replay ({tag})");
                assert_eq!(warm.shadow_validations, 0, "trusted entries skip shadow ({tag})");
                assert!(
                    warm.summary_hits >= warm.summary_replays,
                    "every replay is a hit ({tag})"
                );
                if cold.sharded_jobs == 0 {
                    // Unsharded: no key repeats within a run, so the
                    // record → validate → replay phases land exactly on
                    // runs 1 → 2 → 3. (Identical shard sub-programs
                    // share a key, so a sharded run can walk the whole
                    // protocol internally — only parity is pinned there.)
                    assert_eq!(cold.summary_replays, 0, "nothing to replay cold ({tag})");
                    assert!(validated.shadow_validations > 0, "run 2 must validate ({tag})");
                    assert_eq!(validated.summary_replays, 0, "run 2 still steps ({tag})");
                }
            }
        }
    }
}

#[test]
fn poisoned_summary_is_discarded_and_stepped_result_wins() {
    let layers = steady_layers();
    let spec = spec_for(&layers, 1, SHARD_OFF, true);
    let engine = SweepEngine::new();
    let cold = engine.run(&spec).unwrap();

    // Poison one recorded (still untrusted) summary: bump its last
    // counter delta. It still decodes — only the bit-exact shadow
    // comparison can tell it from the truth.
    let entries = engine.summary_cache().entries();
    assert!(!entries.is_empty());
    let (key, entry) = entries.into_iter().next().unwrap();
    let mut words = entry.summary.to_words();
    let last = words.len() - 1;
    words[last] = words[last].wrapping_add(1);
    let poisoned = ProgramSummary::from_words(&words).expect("tampered summary still decodes");
    assert!(!entry.summary.replays_identically(&poisoned));
    engine.summary_cache().record(key, poisoned);

    // Shadow validation detects the mismatch: the stepped result wins,
    // nothing replays, and the poisoned entry is replaced by a fresh
    // untrusted recording.
    let stepped = engine.run(&spec).unwrap();
    assert_eq!(stepped.results, cold.results, "stepped truth wins over poison");
    assert_eq!(stepped.summary_replays, 0, "a poisoned entry must never replay");
    assert!(stepped.shadow_validations > 0);
    assert!(
        engine.summary_cache().entries().iter().all(|(k, e)| *k != key || !e.trusted),
        "a mismatching recording must not be published"
    );

    // The clean re-recording earns trust on the next pass and replays
    // after that — recovery is complete.
    let validated = engine.run(&spec).unwrap();
    assert_eq!(validated.results, cold.results);
    let warm = engine.run(&spec).unwrap();
    assert_eq!(warm.results, cold.results);
    assert!(warm.summary_replays > 0, "recovered entry must replay");
}

#[test]
fn persisted_trusted_summaries_replay_after_reload() {
    let layers = steady_layers();
    let spec = spec_for(&layers, 1, SHARD_OFF, true);
    let source = SweepEngine::new();
    let reference = source.run(&spec).unwrap();
    source.run(&spec).unwrap(); // shadow-validate → trusted
    let (blob, _, _, n_summaries) = source.export_cache(None);
    assert!(n_summaries > 0, "export must carry the summary records");

    // A fresh engine loading the blob replays on its very first run:
    // trust earned (by bit-exact shadow validation) before the save
    // survives the round-trip.
    let fresh = SweepEngine::new();
    fresh.load_cache_bytes(&blob).unwrap();
    assert_eq!(fresh.cached_summaries(), source.cached_summaries());
    assert!(
        fresh.summary_cache().entries().iter().any(|(_, e)| e.trusted),
        "trust flags must persist"
    );
    let warm = fresh.run(&spec).unwrap();
    assert_eq!(warm.results, reference.results, "reloaded replay must be bit-identical");
    assert!(warm.summary_replays > 0, "first run after reload must replay");
    assert_eq!(warm.shadow_validations, 0, "persisted trust skips shadow validation");
}

/// Recompute the blob's trailing FNV-1a footer so only the deliberate
/// corruption is wrong (a plain byte flip would just trip the checksum).
fn refooter(mut bytes: Vec<u8>) -> Vec<u8> {
    let n = bytes.len() - 8;
    let sum = fp_bytes(FP_SEED, &bytes[..n]);
    bytes[n..].copy_from_slice(&sum.to_le_bytes());
    bytes
}

#[test]
fn corrupt_summary_section_rejects_the_blob_and_engine_falls_back_cold() {
    let layers = steady_layers();
    let spec = spec_for(&layers, 1, SHARD_OFF, true);
    let source = SweepEngine::new();
    source.run(&spec).unwrap();
    let (blob, _, _, n_summaries) = source.export_cache(None);
    assert!(n_summaries > 0);

    // Locate the summary section from the end of the blob: it is the
    // last section before the 8-byte footer, sized by its own records.
    let summary_bytes: usize = source
        .summary_cache()
        .entries()
        .iter()
        .map(|(_, e)| (3 + e.summary.to_words().len()) * 8)
        .sum();
    let count_at = blob.len() - 8 - summary_bytes - 8;
    // Break the first record's trust tag (a strict 0-or-1 field).
    let mut bad = blob.clone();
    bad[count_at + 16..count_at + 24].copy_from_slice(&7u64.to_le_bytes());
    let err = SweepEngine::new().load_cache_bytes(&refooter(bad)).unwrap_err().to_string();
    assert!(err.contains("trust tag"), "{err}");
    // A plain byte flip in the section trips the checksum instead.
    let mut flipped = blob.clone();
    flipped[count_at + 8] ^= 0xFF;
    assert!(SweepEngine::new().load_cache_bytes(&flipped).is_err());

    // Either way the rejection is total — the engine stays cold and
    // fully usable (load merged nothing, a fresh run still works).
    let fresh = SweepEngine::new();
    assert!(fresh.load_cache_bytes(&refooter({
        let mut b = blob.clone();
        b[count_at + 16..count_at + 24].copy_from_slice(&7u64.to_le_bytes());
        b
    }))
    .is_err());
    assert_eq!(fresh.cached_sims(), 0);
    assert_eq!(fresh.cached_summaries(), 0);
    let out = fresh.run(&spec).unwrap();
    assert!(out.executed_sims > 0, "cold fallback simulates normally");
    // Sanity: the pristine blob still loads.
    assert!(SweepEngine::new().load_cache_bytes(&blob).is_ok());
}

#[test]
fn version_2_blobs_load_with_zero_summaries() {
    // A v3 blob with an empty summary section (summary cache off for
    // the producing run) differs from a v2 file only by the version tag
    // and the trailing zero summary count — strip both to fabricate the
    // exact bytes a pre-summary build would have written.
    let layers = steady_layers();
    let engine = SweepEngine::new();
    engine
        .run(
            &SweepSpec::new(SpeedConfig::default())
                .network("t", layers)
                .precisions(vec![Precision::Int8])
                .strategies(vec![Strategy::Mixed])
                .threads(1)
                .summary_cache(false),
        )
        .unwrap();
    let (blob, n_memo, _, n_summaries) = engine.export_cache(None);
    assert!(n_memo > 0);
    assert_eq!(n_summaries, 0);

    let mut v2 = blob.clone();
    v2[8..12].copy_from_slice(&2u32.to_le_bytes());
    let cut = v2.len() - 8 - 8; // the empty summary count, before the footer
    v2.drain(cut..cut + 8);
    let v2 = refooter(v2);

    let fresh = SweepEngine::new();
    let loaded = fresh.load_cache_bytes(&v2).unwrap();
    assert_eq!(loaded, n_memo, "every v2 memo entry must merge");
    assert_eq!(fresh.cached_summaries(), 0, "v2 files carry no summaries");
}
